"""Pallas kernel sweeps vs the pure-jnp ref.py oracles.

Per the kernel contract:
  * freq_level: exact integer match (no float path after the codes);
  * hash_encode: exact match except at floor boundaries, where independent
    f32 summation orders may legitimately differ by one bucket (|diff| <= 1
    and only where the pre-floor value is within eps of an integer);
  * weighted_lp: allclose in f32.

All Pallas calls run with interpret=True on CPU (the kernel body itself is
executed), matching how the kernels are validated off-TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref

# Pallas-interpret runs grid cells in Python -> keep shapes moderate.
_SHAPES = [
    (64, 16, 24, 4),  # (n, d, beta, Q)
    (300, 40, 70, 9),
    (257, 33, 128, 3),  # non-multiples exercise wrapper padding
    (512, 128, 64, 8),
]


def _mk(n, d, beta, Q, seed=0, int_vals=False):
    rng = np.random.default_rng(seed)
    if int_vals:
        pts = rng.integers(0, 1000, (n, d)).astype(np.float32)
        qs = rng.integers(0, 1000, (Q, d)).astype(np.float32)
    else:
        pts = rng.uniform(0, 1000, (n, d)).astype(np.float32)
        qs = rng.uniform(0, 1000, (Q, d)).astype(np.float32)
    w = rng.uniform(1, 10, d).astype(np.float32)
    proj = rng.normal(0, 1, (d, beta)).astype(np.float32)
    b = rng.uniform(0, 729.0, beta)
    b_int = np.floor(b).astype(np.int32)
    b_frac = (b - b_int).astype(np.float32)
    return pts, qs, w, proj, b_int, b_frac


def _boundary_ok(diff, u):
    """Mismatches must be |1| and only where u is ~at an integer boundary."""
    if not diff.any():
        return True
    if np.abs(diff[diff != 0]).max() > 1:
        return False
    frac = np.abs(u - np.round(u))
    return bool(np.all(frac[diff != 0] < 1e-2))


@pytest.mark.parametrize("shape", _SHAPES, ids=str)
def test_hash_encode_sweep(shape):
    n, d, beta, Q = shape
    pts, _, w, proj, b_int, b_frac = _mk(n, d, beta, Q)
    width = 37.5
    got_ref = np.array(
        ops.hash_encode(pts, w, proj, b_int, b_frac, width, use_pallas=False)
    )
    got_pal = np.array(
        ops.hash_encode(pts, w, proj, b_int, b_frac, width, use_pallas=True,
                        interpret=True, bn=128, bb=64, bd=64)
    )
    u = (pts * w) @ proj / width + b_frac
    assert _boundary_ok(got_pal - got_ref, u)
    mismatch = np.mean(got_pal != got_ref)
    assert mismatch < 1e-3  # boundary jitter must stay rare


@pytest.mark.parametrize("shape", _SHAPES, ids=str)
@pytest.mark.parametrize("c,n_levels", [(2, 10), (3, 7)])
def test_freq_level_sweep(shape, c, n_levels):
    n, d, beta, Q = shape
    pts, qs, w, proj, b_int, b_frac = _mk(n, d, beta, Q, seed=1)
    cp = np.array(ops.hash_encode(pts, w, proj, b_int, b_frac, 10.0,
                                  use_pallas=False))
    cq = np.array(ops.hash_encode(qs, w, proj, b_int, b_frac, 10.0,
                                  use_pallas=False))
    rng = np.random.default_rng(2)
    mu = rng.integers(1, max(2, beta // 3), Q).astype(np.int32)
    beta_q = rng.integers(1, beta + 1, Q).astype(np.int32)
    got_ref = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=n_levels,
                                      beta_q=beta_q, use_pallas=False))
    got_pal = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=n_levels,
                                      beta_q=beta_q, use_pallas=True,
                                      interpret=True, bn=128))
    np.testing.assert_array_equal(got_ref, got_pal)


def test_freq_level_semantics_bruteforce():
    """ref.freq_level == brute-force per-level collision counting."""
    rng = np.random.default_rng(3)
    n, beta, Q, c, L = 80, 12, 5, 3, 6
    cp = rng.integers(-(c**L), c**L, (n, beta)).astype(np.int32)
    cq = rng.integers(-(c**L), c**L, (Q, beta)).astype(np.int32)
    mu = rng.integers(1, 6, Q).astype(np.int32)
    got = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=L,
                                  use_pallas=False))
    for qi in range(Q):
        for pi in range(n):
            first = L + 1
            for j in range(L + 1):
                cnt = np.sum(
                    (cp[pi] // (c**j)) == (cq[qi] // (c**j))
                )
                if cnt >= mu[qi]:
                    first = j
                    break
            assert got[qi, pi] == first


def test_freq_level_monotone_in_mu():
    """Larger mu can only delay the first frequent level."""
    rng = np.random.default_rng(4)
    cp = rng.integers(0, 729, (64, 16)).astype(np.int32)
    cq = rng.integers(0, 729, (4, 16)).astype(np.int32)
    prev = None
    for mu in (1, 3, 6, 12):
        cur = np.array(
            ops.freq_level(cp, cq, mu, c=3, n_levels=6, use_pallas=False)
        )
        if prev is not None:
            assert np.all(cur >= prev)
        prev = cur


def test_count_level_matches_numpy():
    rng = np.random.default_rng(5)
    cp = rng.integers(0, 500, (100, 20)).astype(np.int32)
    cq = rng.integers(0, 500, (6, 20)).astype(np.int32)
    for lvl in (0, 1, 3):
        got = np.array(ref.count_level_ref(cp, cq, c=3, level=lvl))
        want = (
            (cq[:, None, :] // 3**lvl) == (cp[None, :, :] // 3**lvl)
        ).sum(-1)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", _SHAPES[:3], ids=str)
@pytest.mark.parametrize("p", [0.5, 1.0, 1.5])
def test_weighted_lp_sweep(shape, p):
    n, d, beta, Q = shape
    pts, qs, w, *_ = _mk(n, d, beta, Q, seed=6)
    got_ref = np.array(ops.weighted_lp_dist(qs, pts, w, p, use_pallas=False))
    got_pal = np.array(ops.weighted_lp_dist(qs, pts, w, p, use_pallas=True,
                                            interpret=True, bn=128, bd=64))
    np.testing.assert_allclose(got_ref, got_pal, rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("p", [1.0, 2.0])
def test_weighted_lp_vs_host_oracle(p):
    from repro.core.distances import weighted_lp_np

    pts, qs, w, *_ = _mk(150, 32, 8, 7, seed=7)
    got = np.array(ops.weighted_lp_dist(qs, pts, w, p))
    want = np.stack([weighted_lp_np(pts, q, w.astype(np.float64), p)
                     for q in qs])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_lp_dtypes(dtype):
    pts, qs, w, *_ = _mk(64, 16, 4, 3, seed=8)
    got = np.array(
        ops.weighted_lp_dist(
            jnp.asarray(qs, dtype), jnp.asarray(pts, dtype),
            jnp.asarray(w, jnp.float32), 2.0, use_pallas=False,
        )
    )
    ref32 = np.array(ops.weighted_lp_dist(qs, pts, w, 2.0, use_pallas=False))
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(got, ref32, rtol=tol, atol=tol * 1e3)


@settings(max_examples=15)
@given(
    n=st.integers(8, 96),
    beta=st.integers(2, 24),
    q=st.integers(1, 6),
    c=st.sampled_from([2, 3]),
    seed=st.integers(0, 10_000),
)
def test_property_freq_level_pallas_equals_ref(n, beta, q, c, seed):
    rng = np.random.default_rng(seed)
    L = 5
    cp = rng.integers(-(c**L) * 2, (c**L) * 2, (n, beta)).astype(np.int32)
    cq = rng.integers(-(c**L) * 2, (c**L) * 2, (q, beta)).astype(np.int32)
    mu = rng.integers(1, beta + 1, q).astype(np.int32)
    a = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=L,
                                use_pallas=False))
    b = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=L, use_pallas=True,
                                interpret=True, bn=64))
    np.testing.assert_array_equal(a, b)


def test_hash_encode_matches_host_family():
    """Kernel path must agree with core.families.hash_codes_np (the planner's
    oracle) — the int split of b* is exactness-critical."""
    from repro.core.families import hash_codes_np, sample_lp_family

    rng = np.random.default_rng(9)
    pts = rng.integers(0, 10_000, (128, 24)).astype(np.float32)
    wc = rng.uniform(1, 10, 24)
    fam = sample_lp_family(d=24, beta=16, p=2.0, width=50.0,
                           center_weight=wc, ratio_cap=1e5, c=3, seed=2)
    want = hash_codes_np(pts, fam)
    got = np.array(
        ops.hash_encode(
            pts, fam.center_weight, fam.proj, fam.b_int, fam.b_frac,
            fam.width, use_pallas=False,
        )
    )
    diff = got - want
    u = (pts * fam.center_weight) @ fam.proj / fam.width + fam.b_frac
    assert _boundary_ok(diff, u)
    assert np.mean(diff != 0) < 1e-3
