"""Collision-probability functions: closed forms, quadrature, Assumption 1."""

from __future__ import annotations

import numpy as np
import pytest
from _hyp import given, st

from repro.core.collision import (
    collision_prob,
    collision_prob_l1,
    collision_prob_l2,
    _collision_prob_numeric,
)


def test_closed_form_l2_matches_quadrature():
    r = np.geomspace(0.05, 50.0, 40)
    closed = collision_prob_l2(r, w=4.0)
    numeric = _collision_prob_numeric(r, w=4.0, p=2.0, n_quad=4096)
    np.testing.assert_allclose(closed, numeric, atol=2e-3)


def test_closed_form_l1_matches_quadrature():
    r = np.geomspace(0.05, 50.0, 40)
    closed = collision_prob_l1(r, w=4.0)
    numeric = _collision_prob_numeric(r, w=4.0, p=1.0, n_quad=4096)
    np.testing.assert_allclose(closed, numeric, atol=2e-3)


@pytest.mark.parametrize("p", [0.5, 1.0, 1.5, 2.0])
def test_assumption1_monotone_decreasing(p):
    """Paper Assumption 1: P(r) inversely proportional to (decreasing in) r."""
    r = np.geomspace(0.01, 100.0, 200)
    pr = collision_prob(r, w=4.0, p=p)
    assert np.all(np.diff(pr) <= 1e-12)


@pytest.mark.parametrize("p", [0.5, 1.0, 1.5, 2.0])
def test_bounds_and_limits(p):
    r = np.geomspace(1e-3, 1e4, 64)
    pr = collision_prob(r, w=4.0, p=p)
    assert np.all(pr >= 0.0) and np.all(pr <= 1.0)
    # r -> 0: always collide;  r -> inf: never collide.
    assert collision_prob(1e-6, 4.0, p) > 0.99
    assert collision_prob(1e6, 4.0, p) < 0.01


@given(
    r=st.floats(0.01, 1e3),
    w=st.floats(0.1, 100.0),
    p=st.sampled_from([0.5, 0.8, 1.0, 1.3, 2.0]),
)
def test_property_valid_probability(r, w, p):
    pr = collision_prob(r, w, p)
    assert 0.0 <= pr <= 1.0


def test_scale_invariance():
    """P depends on r/w only: P(r, w) == P(ar, aw)."""
    r = np.geomspace(0.1, 10.0, 16)
    for p in (1.0, 2.0, 1.5):
        a = collision_prob(r, 4.0, p)
        b = collision_prob(3.7 * r, 3.7 * 4.0, p)
        np.testing.assert_allclose(a, b, atol=3e-3)


def test_rejects_bad_args():
    with pytest.raises(ValueError):
        collision_prob(1.0, -1.0, 2.0)
    with pytest.raises(ValueError):
        collision_prob(1.0, 4.0, 2.5)
