"""Per-architecture smoke tests: reduced configs, one forward/train/decode
step on CPU, shape + finiteness assertions (assignment requirement f)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, ShapeConfig, get_config, reduced
from repro.models import build_model, count_params, init_params, make_batch
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import init_train_state, make_train_step

_LM_ARCHS = [a for a in ARCHS if a != "wlsh_index"]
_SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def smoke_models():
    return {}


def _build(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg, mesh=None)
    params = init_params(model.defs(), jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", _LM_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params = _build(arch)
    batch = make_batch(cfg, _SMOKE_SHAPE, seed=1)
    x = model.hidden_states(params, batch)
    assert x.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


@pytest.mark.parametrize("arch", _LM_ARCHS)
def test_train_step_runs_and_loss_finite(arch):
    cfg, model, params = _build(arch)
    batch = make_batch(cfg, _SMOKE_SHAPE, seed=2)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(model, ocfg)
    state = init_train_state(model.defs(), params, ocfg)
    state, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    # random tokens: loss ~= ln(vocab)
    assert 0.0 < loss < 2.0 * np.log(cfg.vocab)
    assert int(metrics["step"]) == 1


@pytest.mark.parametrize("arch", _LM_ARCHS)
def test_decode_step_matches_cache_semantics(arch):
    cfg, model, params = _build(arch)
    B, cache_len = 2, 16
    cache = model.init_cache(B, cache_len)
    tokens = jnp.array([3, 5], jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, tokens, jnp.int32(0)
    )
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache must actually change
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2))
    )
    assert changed


@pytest.mark.parametrize("arch", ["olmo_1b", "mamba2_780m", "zamba2_1p2b"])
def test_prefill_decode_consistency(arch):
    """Greedy next-token from prefill == decode-steps-by-one (same params)."""
    cfg, model, params = _build(arch)
    B, S = 1, 8
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    full_logits = model.prefill(params, {"tokens": toks})
    cache = model.init_cache(B, S + 1)
    logits = None
    for t in range(S):
        logits, cache = model.decode_step(
            params, cache, toks[:, t], jnp.int32(t)
        )
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32),
        np.asarray(logits, np.float32),
        rtol=0.06, atol=0.05,  # bf16 accumulation differences
    )


def test_param_counts_full_configs():
    """Full (unreduced) configs must land near their nameplate sizes."""
    from repro.models.params import abstract_params

    expect = {
        "llama3_405b": (380e9, 430e9),
        "olmo_1b": (0.9e9, 1.6e9),
        "minicpm_2b": (2.0e9, 3.3e9),
        "h2o_danube_3_4b": (3.0e9, 4.5e9),
        "chameleon_34b": (32e9, 36e9),
        "mamba2_780m": (0.6e9, 1.0e9),
        "zamba2_1p2b": (1.0e9, 1.6e9),
        # assigned shape is 48L x 64e x d_ff 1408 (the HF model is 27L);
        # at the assigned depth the routed experts alone are ~26.6B.
        "moonshot_v1_16b_a3b": (25e9, 31e9),
        "olmoe_1b_7b": (6e9, 8e9),
        "musicgen_medium": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        model = build_model(cfg, mesh=None)
        n = count_params(model.defs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9}, {hi/1e9}]"


def test_nonparametric_ln_olmo():
    """olmo-1b uses non-parametric LN: no scale/bias params in norms."""
    cfg = get_config("olmo_1b")
    assert cfg.norm == "nonparametric_ln"
    model = build_model(reduced(cfg), mesh=None)
    defs = model.defs()
    assert defs["final_norm"] == {} or not jax.tree.leaves(defs["final_norm"])


def test_swa_ring_buffer_window():
    """h2o-danube SWA cache is window-sized, not seq-sized."""
    cfg = reduced(get_config("h2o_danube_3_4b"))
    assert cfg.sliding_window > 0
    model = build_model(cfg, mesh=None)
    shapes = model.cache_shapes(batch=2, cache_len=1_000)
    assert shapes["k"].shape[2] == cfg.sliding_window


def test_moe_routing_is_sparse():
    """MoE forward must route each token to exactly top_k experts."""
    from repro.models.moe import capacity

    cfg = reduced(get_config("olmoe_1b_7b"))
    assert cfg.n_experts == 8 and cfg.top_k == 2
    c = capacity(64, cfg)
    assert c >= 64 * cfg.top_k // cfg.n_experts
