"""Multi-tenant QoS: admission, weighted fairness, SLO-aware degradation.

The traffic-shaping layer must hold its contracts *deterministically* —
everything here runs on a ``ManualClock`` (or no clock at all), with the
fairness and admission invariants property-tested under hypothesis and
the executor faults injected through ``tests/_faults.py``:

* **token-bucket conservation** — over any take schedule the admitted
  count never exceeds ``burst + rate * elapsed``, and a drained bucket
  readmits after ``1/rate`` seconds;
* **no starvation / work conservation** — budgeted deficit-round-robin
  ticks drain every backlogged tenant in bounded calls, never idling a
  tick while the budget covers a pending launch;
* **priority monotonicity** — a higher-weight tenant is never behind a
  lower-weight one while both stay backlogged, and end-to-end its mean
  wait under contention is no worse;
* **SLO-aware degradation** — sustained overload steps only *degradable*
  tenants down the pre-planned (c, k) ladder; every rung is bit-exact
  with the host oracle queried at the rung's relaxed parameters, recall
  stays above the rung's planned bound for every p in {2, 1, 0.5},
  recovery is bit-exact strict, and no rung switch ever compiles;
* **fault containment** — injected restore/build faults are retried
  with bounded doubling backoff, a failing prefetch is written off as
  ``n_prefetch_wasted`` without ever deadlocking the pinned group, and
  a driven replay stays bit-exact through transient faults;
* **shutdown** — ``stop(drain=True)`` raced against concurrent submits
  and streaming inserts drops no future and never ticks after join.
"""

from __future__ import annotations

import threading

import numpy as np
import pytest

from _faults import FaultyExecutor, InjectedFault, record_backoffs
from _hyp import given, settings, st
from conftest import build_parity_service
from repro.serving import (
    AsyncRetrievalService,
    DeficitRoundRobin,
    DegradeStep,
    ManualClock,
    Overloaded,
    QosClass,
    QosScheduler,
    RateLimited,
    RetrievalService,
    ServiceConfig,
    ServiceDriver,
    TokenBucket,
    replay_open_loop,
)

K = 5
LADDER = (DegradeStep(c=4, k=3, cost=0.5, recall_bound=0.3),)


# ------------------------------------------------------------- construction


def test_qos_class_validation():
    with pytest.raises(ValueError, match="non-empty"):
        QosClass("")
    with pytest.raises(ValueError, match="weight"):
        QosClass("t", weight=0.0)
    with pytest.raises(ValueError, match="rate"):
        QosClass("t", rate=-1.0)
    with pytest.raises(ValueError, match="burst"):
        QosClass("t", rate=1.0, burst=0.5)
    with pytest.raises(ValueError, match="slo_ms"):
        QosClass("t", slo_ms=-1.0)


def test_degrade_step_validation():
    with pytest.raises(ValueError, match="integer c"):
        DegradeStep(c=1, k=1)
    with pytest.raises(ValueError, match="integer c"):
        DegradeStep(c=2.5, k=1)
    with pytest.raises(ValueError, match="k >= 1"):
        DegradeStep(c=2, k=0)
    with pytest.raises(ValueError, match="cost"):
        DegradeStep(c=2, k=1, cost=0.0)
    with pytest.raises(ValueError, match="recall_bound"):
        DegradeStep(c=2, k=1, recall_bound=1.5)


def test_qos_scheduler_validation():
    with pytest.raises(ValueError, match="at least one"):
        QosScheduler([])
    with pytest.raises(ValueError, match="duplicate"):
        QosScheduler([QosClass("a"), QosClass("a")])
    with pytest.raises(ValueError, match="capacity_per_tick"):
        QosScheduler([QosClass("a")], capacity_per_tick=0.0)
    with pytest.raises(ValueError, match="degrade_after"):
        QosScheduler([QosClass("a")], degrade_after=0)
    with pytest.raises(KeyError):
        QosScheduler([QosClass("a")]).admit("nobody", 0.0)


# -------------------------------------------------------------- token bucket


def test_token_bucket_starts_full_and_refills():
    bucket = TokenBucket(rate=10.0, burst=2.0)
    assert bucket.try_take(0.0) and bucket.try_take(0.0)
    assert not bucket.try_take(0.0)  # drained
    assert not bucket.try_take(0.05)  # half a token: still short
    assert bucket.try_take(0.1)  # 1/rate elapsed -> one token back
    # refill caps at burst, never beyond
    assert bucket.tokens_at(100.0) == 2.0


@given(
    rate=st.floats(0.5, 50.0),
    burst=st.floats(1.0, 8.0),
    gaps=st.lists(st.floats(0.0, 0.5), min_size=1, max_size=60),
)
@settings(max_examples=100, deadline=None)
def test_token_bucket_conservation_property(rate, burst, gaps):
    """Conservation: admits over any window <= burst + rate * elapsed."""
    bucket = TokenBucket(rate, burst)
    now = 0.0
    admitted, times = 0, []
    for gap in gaps:
        now += gap
        times.append(now)
        if bucket.try_take(now):
            admitted += 1
    elapsed = times[-1] - times[0]
    assert admitted <= burst + rate * elapsed + 1e-6
    assert bucket.tokens_at(now) >= 0.0


# ------------------------------------------------------ deficit round robin


@st.composite
def _tenant_queues(draw):
    """Random per-tenant backlogs with weights and per-tenant costs."""
    n = draw(st.integers(1, 5))
    names = [f"t{i}" for i in range(n)]
    weights = {t: draw(st.floats(0.25, 8.0)) for t in names}
    costs = {t: draw(st.sampled_from([0.5, 1.0, 2.0])) for t in names}
    queues = {
        t: [(t, j) for j in range(draw(st.integers(0, 12)))]
        for t in names
    }
    return weights, costs, queues


@given(_tenant_queues())
@settings(max_examples=100, deadline=None)
def test_drr_unbudgeted_select_is_a_permutation(tq):
    """Conservation: with no budget every queued item is served exactly
    once and every drained tenant's deficit resets."""
    weights, costs, queues = tq
    all_items = [item for q in queues.values() for item in q]
    drr = DeficitRoundRobin()
    out = drr.select(
        {t: list(q) for t, q in queues.items()},
        weight_of=weights.__getitem__,
        cost_of=costs.__getitem__,
    )
    assert sorted(out) == sorted(all_items)
    for t in weights:
        assert drr.deficit_of(t) == 0.0


@given(_tenant_queues(), st.floats(2.0, 6.0))
@settings(max_examples=100, deadline=None)
def test_drr_budgeted_ticks_drain_without_starvation(tq, budget):
    """No starvation + work conservation: budgeted ticks (budget >= the
    dearest launch) each serve at least one launch, every backlogged
    tenant is eventually served, and the backlog drains in bounded
    calls — no permanent deferral, no lost or duplicated item."""
    weights, costs, queues = tq
    all_items = [item for q in queues.values() for item in q]
    queues = {t: list(q) for t, q in queues.items()}
    backlogged = {t for t, q in queues.items() if q}
    total = len(all_items)
    drr = DeficitRoundRobin()
    served: list = []
    first_served: dict[str, int] = {}
    calls = 0
    while any(queues.values()):
        got = drr.select(
            queues, weights.__getitem__, costs.__getitem__, budget=budget
        )
        calls += 1
        assert got, "work conservation: backlog pending, budget covers " \
                    "every cost, yet the tick served nothing"
        for item in got:
            first_served.setdefault(item[0], calls)
        served.extend(got)
        assert calls <= total + 8, "drain did not terminate"
    assert sorted(served) == sorted(all_items)  # nothing lost, nothing twice
    assert set(first_served) == backlogged


@given(
    w_hi=st.sampled_from([1.0, 1.5, 2.0, 3.0, 4.0]),
    w_lo=st.sampled_from([1.0, 1.5, 2.0, 3.0, 4.0]),
    m=st.integers(1, 12),
    budget=st.sampled_from([1.0, 2.0, 3.5]),
)
@settings(max_examples=100, deadline=None)
def test_drr_priority_monotonicity_property(w_hi, w_lo, m, budget):
    """While both tenants stay backlogged, the higher-weight tenant's
    served count never falls behind the lower-weight tenant's."""
    if w_hi < w_lo:
        w_hi, w_lo = w_lo, w_hi
    weights = {"a_hi": w_hi, "b_lo": w_lo}
    queues = {t: [(t, j) for j in range(m)] for t in weights}
    drr = DeficitRoundRobin()
    cum = {"a_hi": 0, "b_lo": 0}
    while any(queues.values()):
        got = drr.select(
            queues, weights.__getitem__, lambda t: 1.0, budget=budget
        )
        assert got
        for item in got:
            cum[item[0]] += 1
        if queues["a_hi"]:  # hi still backlogged: must not be behind
            assert cum["a_hi"] >= cum["b_lo"]
    assert cum == {"a_hi": m, "b_lo": m}


def test_drr_weighted_shares_under_contention():
    """A weight-4 tenant drains 4 launches per weight-1 launch while both
    stay backlogged (quantum 1, unit costs, ample per-round budget)."""
    weights = {"gold": 4.0, "bronze": 1.0}
    queues = {t: [(t, j) for j in range(20)] for t in weights}
    drr = DeficitRoundRobin()
    got = drr.select(
        queues, weights.__getitem__, lambda t: 1.0, budget=10.0
    )
    assert sum(1 for it in got if it[0] == "gold") == 8
    assert sum(1 for it in got if it[0] == "bronze") == 2


# ----------------------------------------------------- scheduler unit tests


def _two_class_qos(**kw):
    kw.setdefault("ladder", (DegradeStep(c=4, k=3, cost=0.5),
                             DegradeStep(c=6, k=2, cost=0.25)))
    return QosScheduler(
        [QosClass("gold", weight=4.0, slo_ms=20.0),
         QosClass("bronze", weight=1.0, slo_ms=100.0, degradable=True)],
        **kw,
    )


def test_deadline_for_uses_class_slo_and_falls_back():
    qos = QosScheduler([QosClass("gold", slo_ms=20.0), QosClass("other")])
    assert qos.deadline_for("gold", 1.0, 0.005) == 1.0 + 0.020
    assert qos.deadline_for("other", 1.0, 0.005) == 1.0 + 0.005


def test_admit_counts_and_rate_limits():
    qos = QosScheduler([QosClass("t", rate=10.0, burst=2.0)])
    qos.admit("t", 0.0)
    qos.admit("t", 0.0)
    with pytest.raises(RateLimited) as exc:
        qos.admit("t", 0.0)
    assert exc.value.tenant == "t" and exc.value.rate == 10.0
    qos.admit("t", 0.2)  # bucket refilled
    st_ = qos.stats["t"]
    assert st_.n_admitted == 3 and st_.n_rate_limited == 1


def test_plan_launches_orders_by_deadline_and_weight():
    """Within a tenant, soonest deadline first; across tenants, the
    heavier class is served first and the leftovers register pressure."""
    qos = _two_class_qos(capacity_per_tick=2.0)
    expired = [
        (0.9, 1, "bronze"), (0.5, 0, "gold"), (0.7, 2, "gold"),
        (0.1, 3, "bronze"),
    ]
    got = qos.plan_launches(expired, now=1.0)
    assert got == [(0, "gold"), (2, "gold")]  # gold first, deadline order
    assert qos.overloaded  # bronze deferred past the capacity
    qos.note_idle_tick()
    assert not qos.overloaded


def test_observe_tick_hysteresis_and_rung_caps():
    """degrade_after pressured ticks step degradable tenants one rung
    down; restore_after clear ticks step back up; one bursty tick resets
    the streak; the strict tenant never moves."""
    qos = _two_class_qos(capacity_per_tick=1.0, degrade_after=3,
                         restore_after=2)

    def tick(n_expired: int):
        if n_expired:
            qos.plan_launches(
                [(0.0, g, "bronze") for g in range(n_expired)], now=1.0
            )
        else:
            qos.note_idle_tick()
        qos.observe_tick()

    tick(2), tick(2)
    assert qos.rung_of("bronze") == 0  # 2 < degrade_after
    tick(0)  # burst cleared: the streak resets
    tick(2), tick(2), tick(2)
    assert qos.rung_of("bronze") == 1 and qos.rung_of("gold") == 0
    assert qos.n_degrade_steps == 1
    # at rung 1 the cost halves, so 2 launches now FIT capacity 1 —
    # degradation relieving the overload by design; pressure must stay
    # heavier than the relaxed cost to force the second step
    tick(2)
    assert not qos.overloaded
    tick(3), tick(3), tick(3)
    assert qos.rung_of("bronze") == 2  # second full window, second step
    tick(5), tick(5), tick(5)
    assert qos.rung_of("bronze") == 2  # capped at the ladder depth
    assert qos.cost_of("bronze") == 0.25 and qos.cost_of("gold") == 1.0
    tick(0), tick(0)
    assert qos.rung_of("bronze") == 1
    assert qos.n_restore_steps == 1
    tick(0), tick(0)
    assert qos.rung_of("bronze") == 0
    tick(0), tick(0)
    assert qos.rung_of("bronze") == 0  # floor at strict
    summary = qos.summary()
    assert summary["n_degrade_steps"] == 2
    assert summary["n_restore_steps"] == 2
    assert summary["tenants"]["bronze"]["rung"] == 0


# --------------------------------------------------- service-level serving


def _qos_service(plan, data, qos, q_batch=4, **cfg_kw):
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=q_batch, degrade_ladder=LADDER,
                          **cfg_kw),
    )
    return svc, AsyncRetrievalService(
        svc.batcher, max_delay_ms=5.0, clock=ManualClock(), qos=qos
    )


def _group_queries(data, plan, gi, n, seed=11):
    """n queries all routed to group ``gi`` (its member weight ids)."""
    rng = np.random.default_rng(seed)
    members = np.asarray(plan.groups[gi].member_ids, np.int64)
    wids = rng.choice(members, n)
    qpts = data[rng.choice(len(data), n, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def test_tenants_never_share_a_launch():
    """Per-(group, tenant) buffers: one tenant's queries never ride in
    another tenant's batch, so a relaxed step cannot touch strict
    answers even within one group."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = _two_class_qos()
    svc, asvc = _qos_service(plan, data, qos)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 4)
    futs = [asvc.submit(qpts[i], wids[i],
                        tenant="gold" if i % 2 else "bronze")
            for i in range(3)]
    assert set(asvc.pending_tenant_depths()) == {(gi, "gold"),
                                                 (gi, "bronze")}
    asvc.clock.advance_to(1.0)  # both past their SLO deadlines
    assert asvc.poll() == 2  # one launch per tenant, never merged
    assert all(f.done() for f in futs)
    assert asvc.pending_count == 0


def test_full_buffer_defers_to_the_fair_queue_under_qos():
    """With QoS attached a full buffer must NOT launch inside submit —
    every launch flows through the weighted-fair queue at the next
    tick, so a bursting tenant cannot buy capacity past its share."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = _two_class_qos()
    svc, asvc = _qos_service(plan, data, qos)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 4)
    futs = [asvc.submit(qpts[i], wids[i], tenant="gold") for i in range(4)]
    assert asvc.pending_count == 4  # full, but no launch inside submit
    assert not any(f.done() for f in futs)
    assert asvc.poll() == 1  # deadline NOT expired: launched as "full"
    assert asvc.n_launched_full == 1
    assert all(f.done() for f in futs)


def test_rate_limited_rejects_before_enqueue_and_overload_spends_no_token():
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = QosScheduler([
        QosClass("limited", rate=10.0, burst=1.0),
        QosClass("filler"),
    ])
    svc, asvc = _qos_service(plan, data, qos, max_pending=2)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 4)
    with pytest.raises(KeyError):
        asvc.submit(qpts[0], wids[0], tenant="stranger")
    asvc.submit(qpts[0], wids[0], tenant="limited")
    with pytest.raises(RateLimited):
        asvc.submit(qpts[1], wids[1], tenant="limited")
    assert asvc.pending_count == 1  # the rejected caller enqueued nothing
    asvc.submit(qpts[1], wids[1], tenant="filler")  # depth now 2 == cap
    with pytest.raises(Overloaded):
        asvc.submit(qpts[2], wids[2], tenant="limited")
    # backpressure precedes admission: the Overloaded attempt spent no
    # token, so after the bucket's 1/rate refill the tenant is admitted
    asvc.clock.advance_to(0.1)
    asvc.drain()
    asvc.submit(qpts[2], wids[2], tenant="limited")
    assert qos.stats["limited"].n_admitted == 2
    assert qos.stats["limited"].n_rate_limited == 1
    asvc.drain()


def test_priority_monotonicity_end_to_end_on_manual_clock():
    """Same trace, same SLOs, contended capacity: the weight-4 tenant's
    mean wait is no worse than the weight-1 tenant's."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = QosScheduler(
        [QosClass("hi", weight=4.0, slo_ms=1.0),
         QosClass("lo", weight=1.0, slo_ms=1.0)],
        capacity_per_tick=1.0,
    )
    svc, asvc = _qos_service(plan, data, qos, q_batch=2)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 12)
    arrivals = np.arange(12) * 1e-4  # a burst: all due almost at once
    tenants = ["hi" if i % 2 else "lo" for i in range(12)]
    replay_open_loop(asvc, qpts, wids, arrivals, tenants=tenants)
    s = qos.summary()["tenants"]
    assert s["hi"]["n_resolved"] == 6 and s["lo"]["n_resolved"] == 6
    assert s["hi"]["mean_wait_s"] <= s["lo"]["mean_wait_s"] + 1e-12


def test_replay_stall_guard_catches_undersized_capacity():
    """A capacity below the cheapest launch cost can never fire expired
    work — the replay must fail loudly instead of spinning forever."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = QosScheduler([QosClass("t")], capacity_per_tick=0.25)
    svc, asvc = _qos_service(plan, data, qos)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 2)
    with pytest.raises(RuntimeError, match="stalled"):
        replay_open_loop(asvc, qpts, wids, [0.0, 1e-4],
                         tenants=["t", "t"])


# ------------------------------------------------- degradation ladder recall


def test_degraded_rung_is_bit_exact_vs_relaxed_oracle(parity_setup):
    """Each ladder rung answers bit-exactly like the host oracle queried
    at the rung's relaxed (c, k) — same hashes, same stop conditions —
    with the tail padded -1/inf back to the strict k; degraded recall
    vs the strict answers stays above the rung's planned bound; and
    recovery (rung 0 again) is bit-exact strict.  Per p in {2, 1, 0.5}."""
    p, data, weights, host, plan, _ = parity_setup
    svc = RetrievalService(
        plan, data, cfg=ServiceConfig(k=K, q_batch=4, degrade_ladder=LADDER)
    )
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    svc.batcher.warmup(groups=[gi])  # compiles rung 0 AND rung 1 steps
    n_compiled = svc.step_cache.n_compiled
    assert svc.batcher.n_rungs == 1
    assert svc.batcher.rung_params(1) == (4, 3)
    qpts, wids = _group_queries(data, plan, gi, 8, seed=13)

    def run(rung):
        outs = [svc.batcher.run_batch(gi, qpts[i:i + 4], wids[i:i + 4],
                                      rung=rung)
                for i in (0, 4)]
        return tuple(np.concatenate(parts) for parts in zip(*outs))

    ids0, d0, stop0, chk0 = run(0)
    ids1, d1, stop1, chk1 = run(1)
    step = LADDER[0]
    recalls = []
    for qi in range(len(qpts)):
        want = host.search_dense(qpts[qi], weight_id=int(wids[qi]),
                                 k=step.k, c=step.c)
        np.testing.assert_array_equal(
            ids1[qi, :step.k], want.ids.astype(np.int32),
            err_msg=f"rung-1 ids mismatch at query {qi} (p={p})",
        )
        assert int(stop1[qi]) == want.stats.stop_level
        assert int(chk1[qi]) == want.stats.n_checked
        np.testing.assert_array_equal(ids1[qi, step.k:], -1)
        assert np.all(np.isinf(d1[qi, step.k:]))
        m = ids1[qi, :step.k] >= 0
        np.testing.assert_allclose(
            d1[qi, :step.k][m], want.dists[m], rtol=1e-4, atol=1e-2
        )
        strict = set(ids0[qi][ids0[qi] >= 0].tolist())
        got = set(ids1[qi][ids1[qi] >= 0].tolist())
        recalls.append(len(got & strict) / max(1, len(strict)))
    assert np.mean(recalls) >= step.recall_bound, (
        f"planned rung recall bound violated at p={p}: "
        f"{np.mean(recalls):.3f} < {step.recall_bound}"
    )
    # recovery: strict again, bit-exact with the pre-degradation answers
    ids0b, d0b, stop0b, chk0b = run(0)
    np.testing.assert_array_equal(ids0b, ids0)
    np.testing.assert_array_equal(d0b, d0)
    np.testing.assert_array_equal(stop0b, stop0)
    np.testing.assert_array_equal(chk0b, chk0)
    # every rung switch hit the pre-compiled steps: nothing new compiled
    assert svc.step_cache.n_compiled == n_compiled


def test_overload_degrades_and_recovery_restores_end_to_end():
    """Driver-observed hysteresis on the real service: sustained deferral
    steps the degradable tenant down (answers padded to the strict k,
    counted n_degraded), sustained clear ticks restore rung 0, and the
    strict tenant's rung never moves."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = QosScheduler(
        [QosClass("gold", weight=4.0, slo_ms=2.0),
         QosClass("bronze", weight=1.0, slo_ms=2.0, degradable=True)],
        ladder=LADDER, capacity_per_tick=1.0,
        degrade_after=2, restore_after=2,
    )
    svc, asvc = _qos_service(plan, data, qos, q_batch=2)
    driver = ServiceDriver(asvc, prefetch=None)
    clock = asvc.clock
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 12, seed=17)
    gis = [int(np.argmax([g.n_members for g in plan.groups]))]
    # two expired bronze buffers per tick vs capacity 1 -> one deferred
    # every tick: sustained pressure
    other = next(g for g in range(plan.n_groups) if g not in gis)
    oq, ow = _group_queries(data, plan, other, 6, seed=19)
    i = j = 0
    for tick in range(2):
        asvc.submit(qpts[i], wids[i], deadline=clock(), tenant="bronze")
        asvc.submit(oq[j], ow[j], deadline=clock(), tenant="bronze")
        i, j = i + 1, j + 1
        driver.step()
    assert qos.rung_of("bronze") == 1 and qos.rung_of("gold") == 0
    assert qos.n_degrade_steps == 1
    # a bronze answer served now is degraded: padded past the rung k
    fut = asvc.submit(qpts[i], wids[i], deadline=clock(), tenant="bronze")
    while not fut.done():
        driver.step()
    ans = fut.result()
    assert ans.ids.shape == (K,)
    assert np.all(ans.ids[LADDER[0].k:] == -1)
    assert qos.stats["bronze"].n_degraded >= 1
    # drain the backlog, then sustained clear ticks restore strict
    asvc.drain()
    driver.step(), driver.step()
    assert qos.rung_of("bronze") == 0
    assert qos.n_restore_steps == 1
    # strict again: bit-exact vs the sync frontend on fresh queries
    fut = asvc.submit(qpts[i + 1], wids[i + 1], deadline=clock(),
                      tenant="gold")
    driver.step()
    sync = svc.query(qpts[i + 1][None], [wids[i + 1]])
    np.testing.assert_array_equal(fut.result().ids, sync.ids[0])


# ------------------------------------------------------------ fault injection


def test_transient_faults_retry_with_doubling_backoff():
    ex = FaultyExecutor(fail_restores=2)
    cache = ex.make_cache(max_resident_groups=1, restore_retries=2,
                          retry_backoff_s=0.01)
    backoffs = record_backoffs(cache)
    with cache.lease(0):
        pass
    with cache.lease(1):  # 0 offloaded
        pass
    with cache.lease(0):  # restore fails twice, third attempt lands
        pass
    assert cache.stats.n_restore_retries == 2
    assert cache.stats.n_restores == 1
    assert backoffs == [0.01, 0.02]  # doubling, recorded — never slept
    assert ex.n_calls("restore") == 3


def test_exhausted_retries_propagate_and_heal_in_place():
    ex = FaultyExecutor(fail_builds=float("inf"))
    cache = ex.make_cache(restore_retries=1)
    with pytest.raises(InjectedFault, match="injected"):
        cache.acquire(0)
    assert not cache.is_resident(0)
    assert cache.pin_count(0) == 0  # the failed acquire leaked no pin
    ex.fail_builds = 0  # heal: the next acquire cold-builds cleanly
    with cache.lease(0) as state:
        assert state == ("dev", 0)
    assert cache.stats.n_restore_retries == 1


def test_failed_prefetch_counts_wasted_and_never_deadlocks():
    """The satellite regression: a prefetch whose restore keeps failing
    is written off as n_prefetch_wasted — no exception escapes into the
    tick, the pinned group is untouched, and the group restores fine
    once the fault clears."""
    ex = FaultyExecutor()
    cache = ex.make_cache(max_resident_groups=2, restore_retries=1)
    with cache.lease(0):
        pass
    with cache.lease(1):
        pass
    with cache.lease(2):  # evicts 0 (offloaded)
        pass
    ex.fail_restores = float("inf")
    pinned = cache.acquire(1)  # a launch in flight
    assert cache.prefetch(0) is False  # contained: no raise
    s = cache.stats
    assert s.n_prefetches == 1 and s.n_prefetch_wasted == 1
    assert s.n_restore_retries == 1  # the bounded retry ran inside
    assert not cache.is_resident(0)
    assert cache.pin_count(1) == 1 and pinned == ("dev", 1)
    cache.release(1)  # no deadlock: the pinned lease completes normally
    ex.fail_restores = 0
    with cache.lease(0) as state:  # the eventual acquire restores
        assert state == ("dev", 0)
    assert cache.stats.n_restores == 1


def test_driven_replay_bit_exact_through_transient_restore_faults():
    """End to end: transient restore faults during a driven, paged, QoS
    replay are retried invisibly — every answer stays bit-exact with
    the fault-free sync reference."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = QosScheduler(
        [QosClass("gold", weight=4.0), QosClass("bronze", degradable=True)],
        ladder=LADDER, capacity_per_tick=4.0,
    )
    svc, asvc = _qos_service(plan, data, qos, max_resident_groups=1)
    cache = svc.batcher.state_cache
    real_restore, fail_every = cache._restore, 3
    calls = {"n": 0}

    def flaky_restore(gi, h):
        calls["n"] += 1
        if calls["n"] % fail_every == 0:
            raise InjectedFault(f"injected restore fault (group {gi})")
        return real_restore(gi, h)

    cache._restore = flaky_restore
    driver = ServiceDriver(asvc)
    rng = np.random.default_rng(23)
    wids = rng.integers(0, len(weights), 24)
    qpts = data[rng.choice(len(data), 24, replace=False)].astype(np.float32)
    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, 24))
    tenants = [("gold", "bronze")[i % 2] for i in range(24)]
    from repro.serving import replay_with_driver
    res, _ = replay_with_driver(driver, qpts, wids, arrivals,
                                tenants=tenants)
    sync = svc.query(qpts, wids)
    np.testing.assert_array_equal(res.ids, sync.ids)
    np.testing.assert_array_equal(res.dists, sync.dists)
    assert cache.stats.n_restore_retries >= 1  # faults actually fired
    assert calls["n"] >= fail_every


# ------------------------------------------------------- shutdown regression


def test_stop_drain_resolves_everything_on_manual_clock():
    """Step-driven shutdown: stop(drain=True) on a never-started driver
    resolves every pending future (QoS attached, inserts interleaved)
    and performs no tick."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = _two_class_qos()
    svc, asvc = _qos_service(plan, data, qos, delta_seal_rows=2,
                             delta_reserve_rows=16)
    driver = ServiceDriver(asvc, prefetch=None)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 6)
    w_in = int(plan.groups[gi].member_ids[0])
    futs = []
    for i in range(6):
        futs.append(driver.submit(qpts[i], wids[i],
                                  tenant="gold" if i % 2 else "bronze"))
        if i % 2:
            driver.insert((data[3] + 50_000.0 + i).astype(np.float32),
                          w_in)
    ticks = driver.stats.n_ticks
    driver.stop(drain=True)  # never started: drain still runs
    assert all(f.done() for f in futs)
    assert asvc.pending_count == 0
    assert driver.stats.n_ticks == ticks  # stop never ticks
    assert not driver.running


def test_thread_stop_drain_races_submit_and_insert_drops_no_future():
    """Thread-mode regression: stop(drain=True) racing a feeder thread
    (submits + streaming inserts through the driver's locked
    passthroughs) strands no future — everything submitted resolves —
    and the driver never ticks after its thread joins."""
    p, data, weights, host, plan, _ = build_parity_service(2.0)
    qos = _two_class_qos()
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=4, degrade_ladder=LADDER,
                          delta_seal_rows=2, delta_reserve_rows=16),
    )
    svc.warmup()
    asvc = AsyncRetrievalService(svc.batcher, max_delay_ms=0.5, qos=qos)
    driver = ServiceDriver(asvc, tick_s=0.001)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    qpts, wids = _group_queries(data, plan, gi, 16)
    w_in = int(plan.groups[gi].member_ids[0])
    futs: list = []
    errs: list = []
    started = threading.Event()

    def feeder():
        try:
            for i in range(len(qpts)):
                futs.append(driver.submit(
                    qpts[i], wids[i],
                    tenant="gold" if i % 2 else "bronze",
                ))
                started.set()
                if i % 5 == 0:
                    driver.insert(
                        (data[3] + 50_000.0 + i).astype(np.float32), w_in
                    )
        except Exception as e:  # pragma: no cover - the regression itself
            errs.append(e)

    driver.start()
    t = threading.Thread(target=feeder)
    t.start()
    started.wait(timeout=10.0)
    driver.stop(drain=True)  # races the feeder mid-stream
    t.join(timeout=30.0)
    assert not t.is_alive() and not errs
    assert not driver.running
    ticks = driver.stats.n_ticks
    driver.drain()  # catch submits that landed after stop's drain
    assert len(futs) == len(qpts)
    assert all(f.done() for f in futs), "shutdown dropped futures"
    assert driver.stats.n_ticks == ticks  # no tick after join
    for f in futs:  # answers are well-formed, strict-k shaped
        assert f.result().ids.shape == (K,)
    driver.stop()  # idempotent
