"""Observability layer: trace spans, unified metrics registry, profiling.

Pinned claims:

* the fixed-bucket histogram's interpolated percentiles track a numpy
  oracle to within one bucket width (deterministic and as a hypothesis
  property), without storing samples;
* the registry's exposition surfaces round-trip — Prometheus text
  parses back to the recorded values and the JSON snapshot is the
  ``snapshot()`` dict verbatim — and ``diff`` reports exactly the
  counter deltas;
* the registry is thread-safe: racing increments lose nothing, and a
  thread-mode ``ServiceDriver`` writing metrics while the main thread
  snapshots never corrupts a total;
* every query served with the obs layer on yields exactly one finished
  ``TraceSpan`` with monotone stage timestamps whose ``n_checked`` /
  ``stop_level`` match the engine's returned values, across the sync,
  async and paged frontends;
* spans survive a JSONL export/load round trip;
* turning the obs layer on changes no answer — ids, dists, stop levels
  and n_checked are bit-exact vs the obs-off service per p in
  {2, 1, 0.5}.
"""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.obs import MetricsRegistry, STAGES, TraceSpan, Tracer
from repro.serving import (
    AsyncRetrievalService,
    ManualClock,
    RetrievalService,
    ServiceConfig,
    ServiceDriver,
    replay_open_loop,
)
from repro.serving.qos import QosClass, QosScheduler

K = 5
Q_BATCH = 4


def _mixed_queries(data, weights, n_queries, seed=43):
    rng = np.random.default_rng(seed)
    wids = rng.integers(0, len(weights), n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def _obs_service(plan, data, **cfg_kw):
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=Q_BATCH, obs=True, **cfg_kw),
    )
    svc.warmup()
    return svc


# ------------------------------------------------------------ metrics registry


def test_counter_labels_totals_and_series():
    reg = MetricsRegistry()
    c = reg.counter("wlsh_test_total", "help text")
    c.inc(group=0)
    c.inc(3, group=1)
    c.inc(group=1)
    assert c.value(group=0) == 1
    assert c.value(group=1) == 4
    assert c.value(group=9) == 0  # unseen series reads 0
    assert c.total() == 5
    assert reg.counter("wlsh_test_total") is c  # get-or-create


def test_counter_rejects_negative_and_kind_conflicts():
    reg = MetricsRegistry()
    reg.counter("wlsh_x_total")
    with pytest.raises(ValueError, match="cannot decrease"):
        reg.counter("wlsh_x_total").inc(-1)
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("wlsh_x_total")


def test_gauge_set_add_and_survives_reset():
    reg = MetricsRegistry()
    g = reg.gauge("wlsh_resident_bytes")
    g.set(100.0)
    g.add(-25.0)  # gauges may decrease
    assert g.value() == 75.0
    reg.counter("wlsh_y_total").inc(7)
    reg.reset("wlsh_")
    assert reg.counter("wlsh_y_total").total() == 0
    assert g.value() == 75.0  # gauges describe state, not activity


def test_histogram_percentiles_match_numpy_oracle():
    buckets = tuple(np.linspace(0.05, 1.0, 20))  # width 0.05
    reg = MetricsRegistry()
    h = reg.histogram("wlsh_t_seconds", buckets=buckets)
    rng = np.random.default_rng(5)
    xs = rng.uniform(0.0, 1.0, 2_000)
    for x in xs:
        h.observe(float(x))
    assert h.count() == len(xs)
    assert h.sum() == pytest.approx(float(xs.sum()), rel=1e-9)
    for q in (0.0, 10.0, 50.0, 95.0, 99.0, 100.0):
        got = h.percentile(q)
        want = float(np.percentile(xs, q))
        assert abs(got - want) <= 0.05 + 1e-9, (q, got, want)


@settings(max_examples=50)
@given(
    xs=st.lists(st.floats(min_value=1e-6, max_value=9.0,
                          allow_nan=False), min_size=1, max_size=200),
    qs=st.lists(st.floats(min_value=0.0, max_value=100.0),
                min_size=2, max_size=6),
)
def test_histogram_percentile_bounded_and_monotone(xs, qs):
    h = MetricsRegistry().histogram(
        "wlsh_p_seconds", buckets=tuple(np.linspace(0.5, 10.0, 20)),
    )
    for x in xs:
        h.observe(x)
    ests = [h.percentile(q) for q in sorted(qs)]
    for est in ests:  # clamped to the observed range
        assert min(xs) - 1e-12 <= est <= max(xs) + 1e-12
    for lo, hi in zip(ests, ests[1:]):  # monotone in q
        assert lo <= hi + 1e-12


def test_histogram_empty_and_bad_args():
    reg = MetricsRegistry()
    h = reg.histogram("wlsh_e_seconds")
    assert np.isnan(h.percentile(50.0))
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        h.percentile(101.0)
    with pytest.raises(ValueError, match="ascending"):
        reg.histogram("wlsh_bad_seconds", buckets=(2.0, 1.0))


def _parse_exposition(text):
    """``{name: {labelstr_or_'': value}}`` from Prometheus text lines."""
    out: dict = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        lhs, val = line.rsplit(" ", 1)
        if "{" in lhs:
            name, rest = lhs.split("{", 1)
            key = rest.rstrip("}")
        else:
            name, key = lhs, ""
        out.setdefault(name, {})[key] = float(val)
    return out


def test_text_exposition_parses_back_to_recorded_values():
    reg = MetricsRegistry()
    reg.counter("wlsh_q_total", "queries").inc(3, group=0)
    reg.counter("wlsh_q_total").inc(5, group=1)
    reg.gauge("wlsh_res_bytes", "resident").set(42.0)
    h = reg.histogram("wlsh_w_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    text = reg.to_text()
    assert "# HELP wlsh_q_total queries" in text
    assert "# TYPE wlsh_w_seconds histogram" in text
    parsed = _parse_exposition(text)
    assert parsed["wlsh_q_total"]['group="0"'] == 3
    assert parsed["wlsh_q_total"]['group="1"'] == 5
    assert parsed["wlsh_res_bytes"][""] == 42.0
    # cumulative buckets: non-decreasing, +Inf equals _count
    # (integral edges exposition-format as ints: le="1", not le="1.0")
    bkt = parsed["wlsh_w_seconds_bucket"]
    cum = [bkt['le="0.1"'], bkt['le="1"'], bkt['le="10"'],
           bkt['le="+Inf"']]
    assert cum == sorted(cum)
    assert cum == [1, 3, 4, 4]
    assert parsed["wlsh_w_seconds_count"][""] == 4
    assert parsed["wlsh_w_seconds_sum"][""] == pytest.approx(6.05)


def _unescape_label(value: str) -> str:
    """Invert Prometheus label-value escaping (\\\\, \\", \\n)."""
    out, i = [], 0
    while i < len(value):
        ch = value[i]
        if ch == "\\" and i + 1 < len(value):
            nxt = value[i + 1]
            out.append({"\\": "\\", '"': '"', "n": "\n"}[nxt])
            i += 2
        else:
            out.append(ch)
            i += 1
    return "".join(out)


def test_text_exposition_escapes_hostile_label_values():
    # backslash, double quote and newline in a label value must all be
    # escaped per the exposition format, and the escaped text must
    # unescape back to the original value (lossless round trip)
    hostile = 'ev"il\\x\nnewline'
    reg = MetricsRegistry()
    reg.counter("wlsh_h_total", "hostile").inc(7, tenant=hostile)
    reg.gauge("wlsh_h_gauge").set(1.0, tenant=hostile)
    reg.histogram("wlsh_h_seconds", buckets=(1.0,)).observe(
        0.5, tenant=hostile)
    text = reg.to_text()
    # every emitted line stays a single line (the raw newline never
    # leaks into the output) and the value field still parses
    for line in text.splitlines():
        if line.startswith("#") or not line:
            continue
        float(line.rsplit(" ", 1)[1])
    assert '\ntenant=' not in text.replace("wlsh_h", "")  # no torn lines
    assert 'tenant="ev\\"il\\\\x\\nnewline"' in text
    # parse one hostile line back: extract the quoted value and invert
    # the escaping — it must equal the original label verbatim
    line = next(ln for ln in text.splitlines()
                if ln.startswith("wlsh_h_total{"))
    quoted = line.split('tenant="', 1)[1].rsplit('"}', 1)[0]
    assert _unescape_label(quoted) == hostile
    # and the registry itself still reads the series under the raw key
    assert reg.counter("wlsh_h_total").value(tenant=hostile) == 7


def test_json_snapshot_round_trip_and_diff():
    reg = MetricsRegistry()
    reg.counter("wlsh_a_total").inc(2, group=0)
    reg.gauge("wlsh_b").set(9.0)
    reg.histogram("wlsh_c_seconds").observe(0.2)
    assert json.loads(reg.to_json()) == reg.snapshot()
    before = reg.snapshot()
    reg.counter("wlsh_a_total").inc(3, group=0)
    reg.counter("wlsh_a_total").inc(group=1)
    reg.gauge("wlsh_b").set(1.0)  # non-counters never appear in a diff
    d = reg.diff(before)
    assert d == {"wlsh_a_total": {"group=0": 3, "group=1": 1}}
    assert reg.diff(reg.snapshot()) == {}  # zero deltas dropped
    assert reg.diff(None) == {"wlsh_a_total": {"group=0": 5, "group=1": 1}}


def test_merge_from_sums_counters():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("wlsh_m_total").inc(2, tenant="x")
    b.counter("wlsh_m_total").inc(5, tenant="x")
    b.counter("wlsh_n_total").inc(1)
    a.merge_from(b)
    assert a.counter("wlsh_m_total").value(tenant="x") == 7
    assert a.counter("wlsh_n_total").total() == 1


def test_registry_thread_safety_racing_increments():
    reg = MetricsRegistry()
    c = reg.counter("wlsh_race_total")
    h = reg.histogram("wlsh_race_seconds")
    n_threads, n_incs = 8, 2_000
    stop = threading.Event()

    def writer(tid):
        for i in range(n_incs):
            c.inc(thread=tid % 2)
            h.observe(1e-3 * (i % 7 + 1))

    def reader():
        while not stop.is_set():  # snapshots must never see torn state
            snap = reg.snapshot()
            total = sum(snap["wlsh_race_total"]["series"].values())
            assert 0 <= total <= n_threads * n_incs
            reg.to_text()

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    rt = threading.Thread(target=reader)
    rt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rt.join()
    assert c.total() == n_threads * n_incs
    assert c.value(thread=0) == c.value(thread=1) == c.total() // 2
    assert h.count() == n_threads * n_incs


# ------------------------------------------------------------------ trace spans


def test_span_rejects_unknown_stage_and_tracks_monotone():
    span = TraceSpan(0)
    with pytest.raises(ValueError, match="unknown trace stage"):
        span.mark("teleport", 1.0)
    span.mark("submit", 1.0)
    span.mark("launch", 2.0)
    assert span.monotone
    span.mark("resolve", 1.5)  # before launch: out of order
    assert not span.monotone
    span.mark("resolve", 2.0)  # re-marking overwrites
    assert span.monotone
    assert span.duration_s == 1.0


@settings(max_examples=50)
@given(
    steps=st.lists(
        st.floats(min_value=-1.0, max_value=1.0, allow_nan=False),
        min_size=2, max_size=len(STAGES),
    )
)
def test_span_monotone_iff_stage_times_sorted(steps):
    span = TraceSpan(0)
    times = list(np.cumsum(steps))
    for stage, t in zip(STAGES, times):
        span.mark(stage, t)
    assert span.monotone == (times == sorted(times))


def test_tracer_ring_retention_and_exact_totals():
    tr = Tracer(capacity=4)
    for _ in range(10):
        tr.finish(tr.begin())
    kept = tr.spans()
    assert [s.query_id for s in kept] == [6, 7, 8, 9]  # oldest dropped
    assert tr.n_started == tr.n_finished == 10
    with pytest.raises(ValueError, match=">= 1"):
        Tracer(capacity=0)


def test_tracer_overflow_ledger_invariant():
    # every started span is accounted for: retained, dropped or
    # in flight — the ledger never loses one to ring overflow
    reg = MetricsRegistry()
    tr = Tracer(capacity=4, metrics=reg)
    open_span = tr.begin()  # stays in flight throughout
    for _ in range(9):
        tr.finish(tr.begin())
    assert tr.n_started == 10
    assert tr.n_finished == 9
    assert tr.n_dropped == 5  # 9 finished into a 4-slot ring
    assert tr.n_inflight == 1
    assert len(tr.spans()) == 4
    assert tr.n_started == len(tr.spans()) + tr.n_dropped + tr.n_inflight
    assert tr.n_finished == len(tr.spans()) + tr.n_dropped
    # the drop ledger is also a registry counter when metrics are bound
    assert reg.counter("wlsh_trace_dropped_total").total() == 5
    tr.finish(open_span)
    assert tr.n_inflight == 0
    assert tr.n_dropped == 6


def test_jsonl_export_meta_records_drop_accounting(tmp_path):
    tr = Tracer(capacity=2)
    tr.begin()  # in flight at export time
    for _ in range(5):
        tr.finish(tr.begin())
    path = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(path) == 2  # retained spans only
    meta = Tracer.load_jsonl_meta(path)
    assert meta == {
        "n_started": 6, "n_finished": 5, "n_dropped": 3,
        "n_inflight": 1, "n_retained": 2, "capacity": 2,
    }
    # load_jsonl skips the meta header and returns only spans
    back = Tracer.load_jsonl(path)
    assert [b.query_id for b in back] == [s.query_id for s in tr.spans()]


def test_jsonl_export_round_trip(tmp_path):
    tr = Tracer()
    s = tr.begin(weight_id=3, group_id=1, tenant="gold")
    for i, stage in enumerate(STAGES):
        s.mark(stage, 10.0 + i)
    s.rung, s.n_shards, s.cause = 2, 4, "deadline"
    s.stop_level, s.n_checked = 7, 105
    s.budget, s.budget_capped = 105, True
    tr.finish(s)
    tr.finish(tr.begin())  # a second, mostly-default span
    path = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(path) == 2
    back = Tracer.load_jsonl(path)
    assert [b.to_dict() for b in back] == [x.to_dict() for x in tr.spans()]


# ----------------------------------------------------- spans through the stack


def test_sync_service_emits_one_exact_span_per_query(parity_setup):
    p, data, weights, host, plan, _ = parity_setup
    svc = _obs_service(plan, data)
    qpts, wids = _mixed_queries(data, weights, 14, seed=51)
    res = svc.query(qpts, wids)
    tr = svc.batcher.tracer
    spans = tr.spans()
    assert tr.n_started == tr.n_finished == len(qpts)
    assert [s.query_id for s in spans] == list(range(len(qpts)))
    for qi, s in enumerate(spans):
        assert s.monotone
        assert {"submit", "route", "queue", "launch", "merge",
                "resolve"} <= set(s.stages)
        assert s.weight_id == int(wids[qi])
        assert s.group_id == int(res.group_ids[qi])
        assert s.n_checked == int(res.n_checked[qi])  # engine's own value
        assert s.stop_level == int(res.stop_levels[qi])
        assert s.budget >= s.n_checked > 0
    # profiler attribution covered the launches
    prof = svc.batcher.profiler.summary()
    assert prof["n_compiles"] >= 1
    n_batches = svc.batcher.metrics.counter(
        "wlsh_group_batches_total"
    ).total()
    assert sum(d["count"] for d in prof["dispatch"].values()) == n_batches


def test_async_service_spans_carry_cause_and_wait_histogram(parity_setup):
    p, data, weights, host, plan, _ = parity_setup
    svc = _obs_service(plan, data)
    asvc = AsyncRetrievalService(svc, max_delay_ms=2.0,
                                 clock=ManualClock())
    qpts, wids = _mixed_queries(data, weights, 16, seed=52)
    rng = np.random.default_rng(6)
    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, len(qpts)))
    replay_open_loop(asvc, qpts, wids, arrivals)
    tr = svc.batcher.tracer
    assert tr.n_started == tr.n_finished == len(qpts)
    for s in tr.spans():
        assert s.monotone
        assert s.cause in ("full", "deadline", "drain")
        assert s.stages["resolve"] >= s.stages["submit"]
    wait_h = svc.batcher.metrics.histogram("wlsh_query_wait_seconds")
    assert wait_h.count() == len(qpts)


def test_qos_admitted_spans_mark_admit_and_tenant(parity_setup):
    p, data, weights, host, plan, _ = parity_setup
    svc = _obs_service(plan, data)
    qos = QosScheduler(classes=[QosClass("gold", weight=1.0,
                                         slo_ms=50.0)])
    asvc = AsyncRetrievalService(svc, max_delay_ms=1.0,
                                 clock=ManualClock(), qos=qos)
    qpts, wids = _mixed_queries(data, weights, 6, seed=53)
    futs = [asvc.submit(qpts[i], wids[i], tenant="gold")
            for i in range(len(qpts))]
    asvc.drain()
    assert all(f.done() for f in futs)
    spans = svc.batcher.tracer.spans()
    assert len(spans) == len(qpts)
    for s in spans:
        assert s.tenant == "gold"
        assert "admit" in s.stages
        assert s.monotone


def test_paged_spans_record_restores(parity_setup):
    p, data, weights, host, plan, _ = parity_setup
    svc = _obs_service(plan, data, max_resident_groups=1)
    qpts, wids = _mixed_queries(data, weights, 16, seed=54)
    svc.query(qpts, wids)
    spans = svc.batcher.tracer.spans()
    assert len(spans) == len(qpts)
    assert all(s.monotone for s in spans)
    # cap 1 over >= 3 groups: most launches fault their state back in,
    # and the restore stamp can never precede the launch stamp's floor
    restored = [s for s in spans if "restore" in s.stages]
    assert restored
    for s in restored:
        assert s.stages["restore"] <= s.stages["launch"]
    n_restores = svc.batcher.metrics.counter(
        "wlsh_state_restores_total"
    ).total()
    builds = svc.batcher.metrics.counter(
        "wlsh_state_builds_total"
    ).total()
    assert n_restores + builds > 0


def test_thread_mode_driver_metrics_stay_exact(parity_setup):
    """Driver thread writes the registry while the main thread snapshots;
    totals must come out exact and every query must get its span."""
    p, data, weights, host, plan, _ = parity_setup
    svc = _obs_service(plan, data, max_resident_groups=1)
    asvc = AsyncRetrievalService(svc.batcher, max_delay_ms=0.5)
    driver = ServiceDriver(asvc, tick_s=0.001)
    driver.start()
    qpts, wids = _mixed_queries(data, weights, 8, seed=55)
    futs = []
    for i in range(len(qpts)):
        futs.append(driver.submit(qpts[i], wids[i]))
        svc.batcher.metrics.snapshot()  # concurrent reads must be safe
        svc.batcher.metrics.to_text()
    driver.stop(drain=True)
    assert all(f.done() for f in futs)
    reg = svc.batcher.metrics
    assert reg.counter("wlsh_group_queries_total").total() == len(qpts)
    tr = svc.batcher.tracer
    assert tr.n_started == tr.n_finished == len(qpts)
    sync = svc.query(qpts, wids)  # thread-mode answers stay bit-exact
    got = np.stack([f.result().ids for f in futs])
    np.testing.assert_array_equal(got, sync.ids)


# ------------------------------------------------------------- bit-exactness


def test_obs_on_is_bit_exact_sync_async_paged(parity_setup):
    p, data, weights, host, plan, svc_off = parity_setup
    qpts, wids = _mixed_queries(data, weights, 24, seed=57)
    ref = svc_off.query(qpts, wids)  # the obs-off reference answers

    def _assert_same(res):
        np.testing.assert_array_equal(res.ids, ref.ids)
        np.testing.assert_array_equal(res.dists, ref.dists)
        np.testing.assert_array_equal(res.stop_levels, ref.stop_levels)
        np.testing.assert_array_equal(res.n_checked, ref.n_checked)

    _assert_same(_obs_service(plan, data).query(qpts, wids))
    _assert_same(
        _obs_service(plan, data, max_resident_groups=1).query(qpts, wids)
    )
    asvc = AsyncRetrievalService(_obs_service(plan, data),
                                 max_delay_ms=2.0, clock=ManualClock())
    rng = np.random.default_rng(8)
    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, len(qpts)))
    res, _ = replay_open_loop(asvc, qpts, wids, arrivals)
    _assert_same(res)
