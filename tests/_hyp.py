"""Optional-hypothesis shim for the property tests.

When hypothesis is installed this re-exports the real API unchanged.  On a
clean checkout without it, ``given`` becomes a decorator that skips the test
at run time and ``st``/``settings`` become permissive stand-ins so the
strategy expressions evaluated at module import (``st.composite`` functions,
``st.sampled_from(...)`` in decorators, chained ``.map``/``.filter``) still
parse.  Non-property tests in the same modules keep running either way.
"""

from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on clean checkouts
    import pytest

    HAVE_HYPOTHESIS = False
    HealthCheck = None

    class _AnyStrategy:
        """Absorbs any call/attribute chain and returns itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    class _Settings:
        """No-op replacement for hypothesis.settings (decorator + profiles)."""

        def __call__(self, *_args, **_kwargs):
            return lambda fn: fn

        @staticmethod
        def register_profile(*_args, **_kwargs):
            pass

        @staticmethod
        def load_profile(*_args, **_kwargs):
            pass

    settings = _Settings()

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
