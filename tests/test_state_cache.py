"""Group-state paging under a device-memory budget.

The StateCache must page per-group device states (lazy build, LRU
eviction, host offload/restore) without ever changing an answer: with
``max_resident_groups`` capped below the plan's group count, both
frontends must stay bit-exact vs ``WLSHIndex.search_dense`` for every
supported exponent p in {2, 1, 0.5}, while ``Batcher.stats`` reports the
eviction/restore traffic.  LRU order, pin-during-launch and counter
consistency are property-tested against fake build/offload/restore
executors (no device); the compiled-step cache is pinned to show
eviction never forces a recompilation for same-shape groups.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import build_parity_service
from repro.serving import RetrievalService, ServiceConfig, StateCache
from repro.serving.async_service import (
    AsyncRetrievalService,
    ManualClock,
    replay_open_loop,
)

K = 5


# ------------------------------------------------- fake-executor unit tests


def _fake_cache(cap=None, budget=None, nbytes=lambda gi: 10, log=None,
                offload=True):
    """StateCache over fake build/offload/restore executors (no device)."""
    kw = {}
    if offload:
        kw = dict(offload=lambda state: ("host", state),
                  restore=lambda gi, host: host[1])
    return StateCache(
        build=lambda gi: ("dev", gi),
        nbytes_of=nbytes,
        max_resident_groups=cap,
        device_budget_bytes=budget,
        on_event=(lambda gi, kind: log.append((gi, kind)))
        if log is not None else None,
        **kw,
    )


def test_lru_eviction_order_deterministic():
    log = []
    cache = _fake_cache(cap=2, log=log)
    for gi in (0, 1, 2):  # 2 evicts 0 (LRU), not 1
        with cache.lease(gi):
            pass
    assert cache.resident_group_ids() == (1, 2)
    assert [e for e in log if e[1] == "evict"] == [(0, "evict")]
    with cache.lease(1):  # refresh 1 -> 2 becomes LRU
        pass
    with cache.lease(0):  # restore 0, evicting 2
        pass
    assert cache.resident_group_ids() == (1, 0)
    assert cache.stats.n_builds == 3
    assert cache.stats.n_restores == 1  # 0 came back from its host copy
    assert cache.stats.n_evictions == 2
    assert cache.stats.n_hits == 1


def test_byte_budget_eviction():
    cache = _fake_cache(budget=25, nbytes=lambda gi: 10)
    for gi in (0, 1, 2):
        with cache.lease(gi):
            pass
    assert cache.resident_group_ids() == (1, 2)  # 30 > 25 -> evict LRU
    assert cache.resident_bytes == 20


def test_miss_evicts_before_materializing():
    """The budget must hold at peak residency: on a miss, room is made
    *before* the new state is built/restored (its size is known up
    front), never by going transiently over budget."""
    peaks = []

    def build(gi):
        peaks.append(cache.resident_bytes + 10)
        return ("dev", gi)

    cache = StateCache(
        build=build, nbytes_of=lambda gi: 10, device_budget_bytes=25,
        offload=lambda s: ("host", s), restore=lambda gi, h: build(gi),
    )
    for gi in (0, 1, 2, 0, 1):  # last two restore, not build
        with cache.lease(gi):
            pass
    assert cache.stats.n_restores == 2
    assert peaks and all(p <= 25 for p in peaks)


def test_pinned_states_are_never_evicted():
    cache = _fake_cache(cap=1)
    cache.acquire(0)  # pinned
    with cache.lease(1):  # over budget, but both pinned -> soft budget
        assert cache.n_resident == 2
        with pytest.raises(ValueError):
            cache.evict(1)
    # releasing 1 makes it the only evictable state: budget enforcement
    # must pick it even though 0 is least recently used
    assert cache.resident_group_ids() == (0,)
    assert cache.pin_count(0) == 1
    cache.release(0)
    assert cache.stats.n_evictions == 1


def test_discard_mode_rebuilds_instead_of_restoring():
    cache = _fake_cache(cap=1, offload=False)
    with cache.lease(0):
        pass
    with cache.lease(1):
        pass
    with cache.lease(0):
        pass
    assert cache.stats.n_builds == 3  # 0 was discarded, not offloaded
    assert cache.stats.n_restores == 0


def test_transient_restore_failure_retries_in_place():
    """A restore that raises once (device OOM) is retried inside the
    same acquire — the caller sees a working lease, and the retry is
    counted instead of surfacing as an exception."""
    fail = {"next": True}

    def restore(gi, host):
        if fail["next"]:
            fail["next"] = False
            raise RuntimeError("injected device OOM")
        return host[1]

    cache = StateCache(
        build=lambda gi: ("dev", gi), nbytes_of=lambda gi: 10,
        max_resident_groups=1,
        offload=lambda s: ("host", s), restore=restore,
    )
    with cache.lease(0):
        pass
    with cache.lease(1):  # evicts 0 to host
        pass
    with cache.lease(0) as state:  # transient failure recovers in place
        assert state == ("dev", 0)
    assert cache.stats.n_restore_retries == 1
    assert cache.stats.n_restores == 1
    assert cache.stats.n_builds == 2  # 0 was never rebuilt after offload


def test_failed_restore_keeps_host_copy():
    """A restore that keeps raising past the retry budget must propagate
    *and* leave the host copy in place so a later acquire restores
    instead of silently cold-rebuilding."""
    fail = {"left": 10}

    def restore(gi, host):
        if fail["left"] > 0:
            fail["left"] -= 1
            raise RuntimeError("injected device OOM")
        return host[1]

    cache = StateCache(
        build=lambda gi: ("dev", gi), nbytes_of=lambda gi: 10,
        max_resident_groups=1, restore_retries=2,
        offload=lambda s: ("host", s), restore=restore,
    )
    with cache.lease(0):
        pass
    with cache.lease(1):  # evicts 0 to host
        pass
    with pytest.raises(RuntimeError, match="injected"):
        cache.acquire(0)  # burns 3 attempts (1 + 2 retries), all fail
    assert not cache.is_resident(0)
    assert cache.stats.n_restore_retries == 2
    fail["left"] = 0  # fault clears
    with cache.lease(0) as state:  # retry restores the preserved copy
        assert state == ("dev", 0)
    assert cache.stats.n_restores == 1
    assert cache.stats.n_builds == 2  # 0 was never rebuilt after offload


def test_cache_validation():
    with pytest.raises(ValueError):
        _fake_cache(cap=0)
    with pytest.raises(ValueError):
        _fake_cache(budget=0)
    with pytest.raises(ValueError):
        StateCache(build=lambda gi: gi, nbytes_of=lambda gi: 1,
                   offload=lambda s: s)  # offload without restore
    cache = _fake_cache()
    with pytest.raises(ValueError):
        cache.release(0)  # release without acquire


def test_invalidate_drops_device_and_host_copies():
    """Compaction-driven invalidation: the group's resident state *and*
    its host offload copy are discarded at a bumped version, so the next
    acquire cold-builds; nothing else is touched."""
    log = []
    cache = _fake_cache(cap=1, log=log)
    with cache.lease(0):
        pass
    with cache.lease(1):  # evicts 0 to its host copy
        pass
    assert cache.version_of(0) == 0
    cache.invalidate(0)
    assert cache.version_of(0) == 1
    assert cache.stats.n_invalidations == 1
    with cache.lease(0):  # host copy gone: cold build, not restore
        pass
    assert cache.stats.n_restores == 0
    assert cache.stats.n_builds == 3
    assert (0, "invalidate") in log
    # the resident variant: invalidating a resident group frees its slot
    cache.invalidate(0)
    assert not cache.is_resident(0)
    assert cache.version_of(0) == 2


def test_replace_installs_new_state_at_bumped_version():
    cache = _fake_cache(cap=2)
    with cache.lease(0):
        pass
    cache.replace(0, ("dev", "compacted-0"))
    assert cache.version_of(0) == 1
    assert cache.stats.n_invalidations == 1
    with cache.lease(0) as state:  # hit: the replaced state serves
        assert state == ("dev", "compacted-0")
    assert cache.stats.n_hits == 1 and cache.stats.n_builds == 1
    # replace of a non-resident group installs it (and evicts LRU to fit)
    with cache.lease(1):
        pass
    with cache.lease(2):
        pass
    cache.replace(3, ("dev", "compacted-3"))
    assert cache.is_resident(3) and cache.n_resident == 2


def test_invalidate_and_replace_refuse_pinned_groups():
    cache = _fake_cache()
    cache.acquire(0)
    with pytest.raises(ValueError):
        cache.invalidate(0)
    with pytest.raises(ValueError):
        cache.replace(0, ("dev", "new"))
    cache.release(0)
    cache.invalidate(0)  # unpinned: fine


def test_stale_offload_copy_is_never_restored():
    """A host copy whose version lags the group's current version must be
    dropped, not restored (defense in depth behind eager invalidation)."""
    cache = _fake_cache(cap=1)
    with cache.lease(0):
        pass
    with cache.lease(1):  # 0 offloaded at version 0
        pass
    cache._versions[0] = 7  # simulate an out-of-band version bump
    with cache.lease(0):
        pass
    assert cache.stats.n_restores == 0  # stale copy discarded
    assert cache.stats.n_builds == 3


@st.composite
def _access_trace(draw):
    """Arbitrary group access sequence plus a residency cap."""
    ops = draw(st.lists(st.integers(0, 5), min_size=1, max_size=60))
    cap = draw(st.integers(1, 4))
    return ops, cap


@st.composite
def _versioned_trace(draw):
    """Interleaved accesses and compaction-driven invalidations."""
    ops = draw(st.lists(
        st.tuples(st.sampled_from(["lease", "invalidate", "replace"]),
                  st.integers(0, 3)),
        min_size=1, max_size=60,
    ))
    cap = draw(st.integers(1, 3))
    return ops, cap


@given(_versioned_trace())
@settings(max_examples=100, deadline=None)
def test_versioned_counter_invariants_property(trace):
    """Under arbitrary interleavings of leases, invalidations and
    replaces: every acquire after a version bump rebuilds (never serves
    stale bytes), versions grow monotonically, and the counter identity
    hits + builds + restores == leases holds with n_invalidations equal
    to the version-bump count."""
    ops, cap = trace
    cache = _fake_cache(cap=cap)
    versions = {gi: 0 for gi in range(4)}
    expected = {gi: ("dev", gi) for gi in range(4)}  # current payload
    n_leases = n_bumps = 0
    for op, gi in ops:
        if op == "lease":
            with cache.lease(gi) as state:
                assert state == expected[gi]
            n_leases += 1
        elif op == "invalidate":
            versions[gi] += 1
            n_bumps += 1
            cache.invalidate(gi)
            assert not cache.is_resident(gi)
            expected[gi] = ("dev", gi)  # next acquire cold-builds
        else:
            versions[gi] += 1
            n_bumps += 1
            expected[gi] = ("dev", gi, versions[gi])
            cache.replace(gi, expected[gi])
            assert cache.is_resident(gi)
        assert cache.version_of(gi) == versions[gi]
    s = cache.stats
    assert s.n_hits + s.n_builds + s.n_restores == n_leases
    assert s.n_invalidations == n_bumps
    assert all(cache.version_of(g) == versions[g] for g in versions)


@given(_access_trace())
@settings(max_examples=100, deadline=None)
def test_lru_and_counter_invariants_property(trace):
    """The cache must track a reference LRU model exactly: residency order,
    cap, and hit/build/restore/eviction counter consistency on arbitrary
    access sequences."""
    ops, cap = trace
    cache = _fake_cache(cap=cap)
    model: OrderedDict[int, bool] = OrderedDict()
    seen: set[int] = set()
    for gi in ops:
        with cache.lease(gi) as state:
            assert state == ("dev", gi)
            assert cache.pin_count(gi) == 1
        assert cache.pin_count(gi) == 0
        if gi in model:
            model.move_to_end(gi)
        else:
            model[gi] = True
        seen.add(gi)
        while len(model) > cap:
            model.popitem(last=False)
        assert cache.resident_group_ids() == tuple(model)
    s = cache.stats
    assert s.n_hits + s.n_builds + s.n_restores == len(ops)
    assert s.n_builds == len(seen)  # offload mode: at most one cold build
    assert s.n_restores <= s.n_evictions
    assert cache.n_resident == len(model) <= cap


# ----------------------------------------------- service-level paging tests


def _paged_service(plan, data, cap, q_batch=4):
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=q_batch, max_resident_groups=cap),
    )
    svc.warmup()
    svc.reset_stats()
    return svc


def _mixed_queries(data, weights, n_queries, seed=43):
    rng = np.random.default_rng(seed)
    wids = rng.integers(0, len(weights), n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def test_paged_service_matches_search_dense(parity_setup):
    """Bit-exact vs the host oracle with max_resident_groups < n_groups,
    per p in {2, 1, 0.5}, with live eviction/restore traffic."""
    p, data, weights, host, plan, svc = parity_setup
    assert plan.n_groups >= 3
    psvc = _paged_service(plan, data, cap=1)
    qpts, wids = _mixed_queries(data, weights, 24)
    # submit in small chunks so group launches interleave and page
    res_ids, res_stop = [], []
    for lo in range(0, len(qpts), 4):
        r = psvc.query(qpts[lo : lo + 4], wids[lo : lo + 4])
        res_ids.append(r.ids)
        res_stop.append(r.stop_levels)
    res_ids = np.concatenate(res_ids)
    res_stop = np.concatenate(res_stop)
    for qi in range(len(qpts)):
        want = host.search_dense(qpts[qi], weight_id=int(wids[qi]), k=K)
        np.testing.assert_array_equal(
            res_ids[qi], want.ids.astype(np.int32),
            err_msg=f"paged ids mismatch at query {qi} (p={p})",
        )
        assert int(res_stop[qi]) == want.stats.stop_level
    # the run actually paged: Batcher.stats reports evictions and restores
    evictions = sum(s.n_state_evictions for s in psvc.stats.values())
    restores = sum(s.n_state_restores for s in psvc.stats.values())
    assert evictions > 0 and restores > 0
    assert psvc.state_cache.n_resident == 1


def test_paged_async_frontend_matches_sync(parity_setup):
    """The async frontend over a capped cache stays bit-exact with the
    unpaged sync service on identical traffic, per p in {2, 1, 0.5}."""
    p, data, weights, host, plan, svc = parity_setup
    qpts, wids = _mixed_queries(data, weights, 24, seed=47)
    sync = svc.query(qpts, wids)  # unpaged reference
    psvc = _paged_service(plan, data, cap=1)
    rng = np.random.default_rng(11)
    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, len(qpts)))
    asvc = AsyncRetrievalService(psvc.batcher, max_delay_ms=2.0,
                                 clock=ManualClock())
    res, _ = replay_open_loop(asvc, qpts, wids, arrivals)
    np.testing.assert_array_equal(res.ids, sync.ids)
    np.testing.assert_array_equal(res.dists, sync.dists)
    np.testing.assert_array_equal(res.stop_levels, sync.stop_levels)
    np.testing.assert_array_equal(res.n_checked, sync.n_checked)
    assert psvc.cache_summary()["n_evictions"] > 0


def test_state_pinned_during_launch(parity_setup):
    """While a launch is in flight its group's state is pinned (and the
    budget is temporarily soft); after the launch it is evictable again."""
    p, data, weights, host, plan, svc = parity_setup
    psvc = _paged_service(plan, data, cap=1)
    batcher = psvc.batcher
    observed = []
    orig_encode = batcher._encode

    def spying_encode(gi, cfg, state, queries, take):
        observed.append((gi, batcher.state_cache.pin_count(gi)))
        return orig_encode(gi, cfg, state, queries, take)

    batcher._encode = spying_encode
    try:
        qpts, wids = _mixed_queries(data, weights, 8, seed=13)
        psvc.query(qpts, wids)
    finally:
        batcher._encode = orig_encode
    assert observed and all(pins == 1 for _, pins in observed)
    assert all(
        batcher.state_cache.pin_count(gi) == 0 for gi in range(plan.n_groups)
    )


def test_eviction_does_not_recompile(parity_setup):
    """QueryStepCache keys on shape signatures, not states: serving with a
    capped cache (states paging constantly) must compile exactly the same
    number of steps as full residency, and re-traffic compiles nothing."""
    p, data, weights, host, plan, svc = parity_setup
    psvc = _paged_service(plan, data, cap=1)
    signatures = {
        psvc.group_config(gi).shape_signature()
        for gi in range(plan.n_groups)
    }
    assert psvc.step_cache.n_compiled == len(signatures)
    qpts, wids = _mixed_queries(data, weights, 16, seed=17)
    for lo in range(0, len(qpts), 4):  # interleave groups -> page states
        psvc.query(qpts[lo : lo + 4], wids[lo : lo + 4])
    assert psvc.cache_summary()["n_evictions"] > 0  # paging happened
    assert psvc.step_cache.n_compiled == len(signatures)  # no recompiles


def test_discard_mode_warmup_skips_doomed_builds(parity_setup):
    """With offload disabled, warmup must not build states the budget
    would immediately discard — only the budget-fitting tail prebuilds."""
    p, data, weights, host, plan, svc = parity_setup
    dsvc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=4, max_resident_groups=1,
                          offload_evicted=False),
    )
    dsvc.warmup()
    assert dsvc.cache_summary()["n_builds"] == 1  # not n_groups
    assert dsvc.cache_summary()["n_evictions"] == 0
    # all steps still compiled during warmup, and answers stay exact
    signatures = {
        dsvc.group_config(gi).shape_signature()
        for gi in range(plan.n_groups)
    }
    assert dsvc.step_cache.n_compiled == len(signatures)
    qpts, wids = _mixed_queries(data, weights, 8, seed=19)
    np.testing.assert_array_equal(
        dsvc.query(qpts, wids).ids, svc.query(qpts, wids).ids
    )


def test_state_nbytes_accounts_built_state(parity_setup):
    """IndexConfig.state_nbytes must equal the actual bytes of the built
    (padded) QueryState, so byte budgets are enforceable before build."""
    p, data, weights, host, plan, svc = parity_setup
    svc.warmup()
    import dataclasses

    for gi in range(plan.n_groups):
        state = svc.batcher.state_cache.acquire(gi)
        try:
            actual = sum(
                np.asarray(getattr(state, f.name)).nbytes
                for f in dataclasses.fields(type(state))
            )
        finally:
            svc.batcher.state_cache.release(gi)
        assert svc.group_config(gi).state_nbytes == actual


def test_service_config_rejects_bad_budgets():
    with pytest.raises(ValueError):
        ServiceConfig(max_resident_groups=0)
    with pytest.raises(ValueError):
        ServiceConfig(device_budget_bytes=0)
