"""Sharded big-group serving: strict placement + multi-device parity.

Host-side tests cover the strict sharding-rule contract (the
``spec(strict=True)`` raise, the warn-once replication fallback, range
math, per-shard byte pricing).  Everything needing a populated mesh runs
in a child process under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the main process must keep the single real CPU device), mirroring
tests/test_multidevice.py.

The parity suite pins the acceptance claim: sharded search is bit-exact
(ids, dists, stop, n_checked) with the single-device engine for
p in {2, 1, 0.5}, sync + async, paged + unpaged, including a ragged
(non-divisible) live row count.  Bit-exactness across shard counts
requires identical per-block gemm shapes (f32 matmuls are
shape-sensitive), so the fixtures pin ``block_n`` and pad the row
capacity to a common multiple via ``delta_reserve_rows`` — the same
masked-capacity machinery streaming uses.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.distributed import group_sharding
from repro.distributed.sharding import spec
from repro.index.config import IndexConfig

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


class _FakeMesh:
    """Duck-typed mesh for host-side spec() tests (no devices needed)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


# ------------------------------------------------------- strict placement


def test_spec_strict_raises_on_non_dividing_dim():
    mesh = _FakeMesh(data=8, model=1)
    with pytest.raises(ValueError, match="strict sharding refuses"):
        spec(mesh, ("rows", None), (1003, 16), strict=True)
    # a dividing shape passes strict and shards over the present axes
    p = spec(mesh, ("rows", None), (1008, 16), strict=True)
    assert p == spec(mesh, ("rows", None), (1008, 16))


def test_spec_replication_fallback_warns_once_per_shape():
    mesh = _FakeMesh(data=8, model=1)
    shape = (1001, 3)  # unique shape so the warn-once set can't be primed
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p1 = spec(mesh, ("rows", None), shape)
        p2 = spec(mesh, ("rows", None), shape)
    assert p1 == p2  # replicated fallback, same answer both calls
    msgs = [str(x.message) for x in w if x.category is UserWarning]
    assert len(msgs) == 1, msgs  # once per (name, shape), not per call
    assert "replicating" in msgs[0] and "8x" in msgs[0]


def test_serving_mesh_validates_device_count():
    with pytest.raises(ValueError, match="n_shards must be >= 1"):
        group_sharding.serving_mesh(0)
    import jax

    too_many = jax.device_count() + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        group_sharding.serving_mesh(too_many)
    mesh = group_sharding.serving_mesh(1)
    assert mesh.axis_names == ("data", "model") and mesh.size == 1


def test_host_row_ranges_cover_capacity_evenly():
    assert group_sharding.host_row_ranges(1008, 8) == [
        (s * 126, (s + 1) * 126) for s in range(8)
    ]
    assert group_sharding.host_row_ranges(64, 1) == [(0, 64)]
    with pytest.raises(ValueError, match="does not divide"):
        group_sharding.host_row_ranges(1003, 8)


def test_state_nbytes_prices_the_per_device_slice():
    one = IndexConfig(n=1 << 20, d=32, beta=64, n_shards=1)
    eight = IndexConfig(n=1 << 20, d=32, beta=64, n_shards=8)
    # family (proj + b_int/b_frac + width) + n_valid stay replicated;
    # the row arrays (codes i32 + bf16 vectors) scale 1/8 per device
    family_and_scalars = 32 * 64 * 4 + 64 * (4 + 4) + 4 + 4
    rows_one = one.state_nbytes - family_and_scalars
    rows_eight = eight.state_nbytes - family_and_scalars
    assert rows_one == (1 << 20) * (64 * 4 + 32 * 2)
    assert rows_eight == rows_one // 8
    # shard count is compile-relevant: distinct compiled-step cache keys
    assert one.shape_signature() != eight.shape_signature()
    assert one != eight
    assert np.isfinite(rows_eight)  # sanity: accounting stays integral


# ------------------------------------------------- multi-device parity


_PARITY_SETUP = """
    import numpy as np, jax
    from repro.core.datagen import make_dataset, make_weight_set
    from repro.core.params import PlanConfig
    from repro.core.wlsh import WLSHIndex
    from repro.serving import (AsyncRetrievalService, ManualClock,
                               RetrievalService, ServiceConfig,
                               replay_open_loop)

    assert jax.device_count() == 8
    P_VAL = %(p)s
    # 1003 live rows: ragged under every shard count > 1.  The 5 reserve
    # rows pad the shared capacity to 1008 = 16 * 63, so every shard
    # count runs identical (q, 63, d) block gemms and bit-exactness is
    # structural, not luck (f32 matmuls are shape-sensitive).
    data = make_dataset(n=1003, d=16, seed=41)
    weights = make_weight_set(size=8, d=16, n_subset=4, n_subrange=10,
                              seed=42)
    pcfg = PlanConfig(p=P_VAL, c=3, n=len(data), gamma_n=100.0)
    host = WLSHIndex(data, weights, pcfg, tau=500.0, v=4, v_prime=4,
                     seed=9)
    plan = host.export_serving_plan()
    rng = np.random.default_rng(43)
    NQ = 12
    wids = rng.integers(0, len(weights), NQ)
    qpts = data[rng.choice(len(data), NQ, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)

    def svc_for(shards, **kw):
        svc = RetrievalService(plan, data, cfg=ServiceConfig(
            k=3, q_batch=4, block_n=63, delta_reserve_rows=5,
            n_shards=shards, **kw))
        assert svc.mesh.size == shards
        return svc

    def assert_same(a, b, what):
        np.testing.assert_array_equal(a.ids, b.ids, err_msg=what)
        np.testing.assert_array_equal(
            a.dists.view(np.uint32), b.dists.view(np.uint32), err_msg=what)
        np.testing.assert_array_equal(a.stop_levels, b.stop_levels,
                                      err_msg=what)
        np.testing.assert_array_equal(a.n_checked, b.n_checked,
                                      err_msg=what)
"""


@pytest.mark.slow_parity
@pytest.mark.parametrize("p", [2.0, 1.0, 0.5])
def test_sharded_search_bit_exact_with_unsharded(p):
    """Acceptance: shards in {2, 8} answer bit-identically (ids, dists,
    stop, n_checked) to the single-device engine — sync, async, paged —
    on a ragged (1003-row) corpus, per p."""
    out = _run(_PARITY_SETUP % {"p": p} + """
    base = svc_for(1).query(qpts, wids)
    # the unsharded answers agree with the host oracle, so the sharded
    # ones transitively do too
    for qi in range(NQ):
        want = host.search_dense(qpts[qi], weight_id=int(wids[qi]), k=3)
        np.testing.assert_array_equal(base.ids[qi],
                                      want.ids.astype(np.int32))
        assert int(base.stop_levels[qi]) == want.stats.stop_level
        assert int(base.n_checked[qi]) == want.stats.n_checked
    for shards in (2, 8):
        svc = svc_for(shards)
        assert_same(svc.query(qpts, wids), base, f"sync shards={shards}")
        # paged: one resident group, sharded offload/restore per shard
        paged = svc_for(shards, max_resident_groups=1)
        chunks = [paged.query(qpts[lo:lo + 4], wids[lo:lo + 4])
                  for lo in range(0, NQ, 4)]
        np.testing.assert_array_equal(
            np.concatenate([c.ids for c in chunks]), base.ids,
            err_msg=f"paged shards={shards}")
        np.testing.assert_array_equal(
            np.concatenate([c.n_checked for c in chunks]), base.n_checked)
        # async open-loop replay over the sharded paged service
        arrivals = np.cumsum(rng.exponential(1 / 2000.0, NQ))
        asvc = AsyncRetrievalService(paged.batcher, max_delay_ms=2.0,
                                     clock=ManualClock())
        res_a, _ = replay_open_loop(asvc, qpts, wids, arrivals)
        assert_same(res_a, base, f"async shards={shards}")
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow_parity
def test_sharded_offload_restore_roundtrip_per_shard():
    """Evicting a sharded state keeps one host chunk per shard (replicas
    deduped) and restoring it round-trips the exact device bytes."""
    out = _run(_PARITY_SETUP % {"p": 2.0} + """
    from repro.distributed.group_sharding import (
        offload_state_sharded, restore_state_sharded)

    svc = svc_for(8)
    svc.warmup()
    gi = int(svc.batcher.route(wids)[0])
    with svc.state_cache.lease(gi) as st:
        before_codes = np.asarray(st.codes)
        before_pts = np.asarray(st.points, np.float32)
        host = offload_state_sharded(st)
    assert len(host.codes) == 8 and len(host.points) == 8
    assert all(c.shape[0] == 1008 // 8 for c in host.codes)
    np.testing.assert_array_equal(np.concatenate(host.codes), before_codes)
    restored = restore_state_sharded(svc.mesh, host)
    np.testing.assert_array_equal(np.asarray(restored.codes), before_codes)
    np.testing.assert_array_equal(
        np.asarray(restored.points, np.float32), before_pts)
    assert int(restored.n_valid) == 1003
    # the restored placement is the strict row sharding (8 distinct rows
    # slices, nothing replicated)
    starts = {s.index[0].start or 0 for s in restored.codes.addressable_shards}
    assert len(starts) == 8
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow_parity
def test_per_host_build_matches_materialized_build():
    """``build_group_state(points_loader=...)`` is bit-exact with the
    materialized-corpus build at the same capacity, and the loader only
    ever sees per-shard row ranges — never the whole corpus."""
    out = _run(_PARITY_SETUP % {"p": 2.0} + """
    from repro.index.builder import build_group_state

    svc = svc_for(8)
    gi = int(svc.batcher.route(wids)[0])
    cfg = svc.group_config(gi)
    gplan = plan.groups[gi]
    whole = build_group_state(svc.mesh, cfg, data, gplan)

    calls = []
    def loader(lo, hi):
        calls.append((lo, hi))
        return data[lo:hi]

    hosted = build_group_state(svc.mesh, cfg, None, gplan,
                               points_loader=loader, n_points=len(data))
    assert len(calls) >= 8 - 1  # per-range calls (dead tail range skipped)
    assert all(hi - lo <= 1008 // 8 for lo, hi in calls), calls
    np.testing.assert_array_equal(np.asarray(hosted.codes),
                                  np.asarray(whole.codes))
    np.testing.assert_array_equal(np.asarray(hosted.points, np.float32),
                                  np.asarray(whole.points, np.float32))
    assert int(hosted.n_valid) == int(whole.n_valid) == len(data)

    # misuse is rejected explicitly
    try:
        build_group_state(svc.mesh, cfg, data, gplan,
                          points_loader=loader, n_points=len(data))
        raise AssertionError("points + points_loader must be rejected")
    except ValueError:
        pass
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow_parity
def test_strict_sharding_refuses_non_dividing_capacity_on_mesh():
    """A row capacity that does not divide an 8-device mesh raises the
    strict-mode error at step construction — never a silent 8x replica."""
    out = _run("""
    import jax
    from repro.index.config import IndexConfig
    from repro.index.engine import make_query_step

    assert jax.device_count() == 8
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    cfg = IndexConfig(n=1003, d=16, beta=32, q_batch=4, k=3, block_n=59,
                      vec_dtype="float32", use_pallas=False)
    try:
        make_query_step(mesh, cfg)
        raise AssertionError("non-dividing capacity must raise")
    except ValueError as e:
        assert "strict sharding refuses" in str(e), e
    print("OK")
    """)
    assert "OK" in out
