"""The paper's motivating scenario (Sec. 1): a personalized recommender.

Products are points, user preferences are weight vectors.  When user u
(preference W_u) shows interest in product o, recommend the (c,k)-WNN of o
under D_{W_u}.  This example contrasts:

  * naive:  one C2LSH table group per user          (space: sum of betas)
  * WLSH:   Partition() + derived families share groups across users

and verifies both answer with ratio <= c while WLSH uses a fraction of the
tables.

    PYTHONPATH=src python examples/multi_weight_recsys.py
"""

import numpy as np

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex


def main():
    n_products, d, n_users, k = 6_000, 24, 32, 5
    p = 2.0

    products = make_dataset(n=n_products, d=d, seed=0)
    # user taste clusters: 4 segments x 8 users
    prefs = make_weight_set(size=n_users, d=d, n_subset=4, n_subrange=10,
                            seed=1)
    cfg = PlanConfig(p=p, c=3, n=n_products, gamma_n=100.0)

    wlsh = WLSHIndex(products, prefs, cfg, tau=500.0, v=d // 4,
                     v_prime=d // 4, seed=2)
    naive_tables = 0
    for u in range(n_users):
        solo = WLSHIndex(products, prefs[u : u + 1], cfg, tau=float("inf"),
                         v=d // 4, v_prime=d // 4, seed=2)
        naive_tables += solo.beta_total
    print(f"{n_users} users, {n_products} products")
    print(f"naive per-user tables : {naive_tables}")
    print(f"WLSH shared tables    : {wlsh.beta_total} "
          f"({len(wlsh.part.groups)} groups, "
          f"{naive_tables / wlsh.beta_total:.1f}x saving)")

    rng = np.random.default_rng(3)
    ratios = []
    for u in rng.choice(n_users, 8, replace=False):
        o = products[rng.integers(0, n_products)]
        res = wlsh.search(o, weight_id=int(u), k=k)
        got = res.ids[res.ids >= 0]
        exact = np.sort(weighted_lp_np(products, o, prefs[u], p))[: got.size]
        mine = np.sort(weighted_lp_np(products[got], o, prefs[u], p))
        # +eps on both sides: the query IS a product, so exact[0] == 0
        r = float(np.mean((mine + 1e-9) / (exact + 1e-9)))
        ratios.append(r)
        names = ", ".join(str(i) for i in got[:k])
        print(f"  user {u:2d}: recommend products [{names}]  ratio {r:.3f}")
    print(f"avg overall ratio {np.mean(ratios):.4f} (<= c={cfg.c})")
    assert np.mean(ratios) < cfg.c
    assert wlsh.beta_total < naive_tables


if __name__ == "__main__":
    main()
