"""End-to-end driver: embed a corpus with an assigned-arch backbone, plan
WLSH table groups over every user's preference weight vector, and serve a
mixed stream of weight-personalized k-NN queries through the multi-group
retrieval service.

    PYTHONPATH=src python examples/serve_retrieval.py

This is the paper's recommender-system scenario (Sec. 1) on the framework's
own stack: the LM substrate produces the vectors, the WLSH core partitions
the users' weight vectors into table groups and exports a ServingPlan, and
``RetrievalService`` routes each (query, user) to its group, coalesces
same-group traffic into batches, and shares compiled query steps across
groups with equal padded shapes (single-device mesh here; the same code
lowers to the production meshes in launch/dryrun.py).

The same traffic is then replayed open-loop — one request at a time, at
Poisson arrival times — through the deadline-aware async frontend
(``AsyncRetrievalService``, launch on batch fill or ``max_delay_ms``
expiry), which must answer bit-exactly while recovering most of the batch
occupancy that single-request submission throws away.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.datagen import make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.models import build_model, init_params
from repro.serving import (
    AsyncRetrievalService,
    ManualClock,
    RetrievalService,
    ServiceConfig,
    replay_open_loop,
)


def embed_corpus(n_docs: int, seq_len: int = 32, arch: str = "olmo-1b"):
    """Mean-pooled final hidden states of a reduced backbone = doc vectors."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg, mesh=None)
    params = init_params(model.defs(), jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    vecs = []
    fwd = jax.jit(lambda p, b: model.hidden_states(p, b).mean(axis=1))
    bs = 64
    for i in range(0, n_docs, bs):
        key, k = jax.random.split(key)
        toks = jax.random.randint(k, (min(bs, n_docs - i), seq_len), 0,
                                  cfg.vocab, dtype=jnp.int32)
        vecs.append(np.asarray(fwd(params, {"tokens": toks}), np.float32))
    out = np.concatenate(vecs)
    # shift embeddings to the positive orthant (weighted l_p is used on
    # magnitudes; any affine shift preserves neighbor structure under D_W)
    out = out - out.min(axis=0, keepdims=True)
    return out, cfg


def main():
    n_docs, n_users, n_queries, k = 4_096, 12, 24, 5
    t0 = time.time()
    corpus, cfg_lm = embed_corpus(n_docs)
    d = corpus.shape[1]
    print(f"embedded {n_docs} docs -> ({n_docs}, {d}) "
          f"with {cfg_lm.name} in {time.time() - t0:.1f}s")

    # user preference weight vectors (the paper's S), one group plan for all
    value_range = float(corpus.max())
    users = make_weight_set(size=n_users, d=d, n_subset=3, n_subrange=10,
                            seed=7)
    cfg = PlanConfig(p=2.0, c=3, n=n_docs, gamma_n=100.0)
    host = WLSHIndex(corpus, users, cfg, tau=500.0, v=d // 4, v_prime=d // 4,
                     value_range=value_range, seed=8)
    plan = host.export_serving_plan()
    print(f"WLSH plan: {plan.n_groups} groups, {plan.beta_total} tables, "
          f"group betas {[g.beta_group for g in plan.groups]}")

    # the retrieval service serves *every* group behind one front end
    t0 = time.time()
    svc = RetrievalService(
        plan, corpus, cfg=ServiceConfig(k=k, q_batch=8, use_pallas=False)
    )
    svc.warmup()
    print(f"service: {plan.n_groups} device group states, "
          f"{svc.step_cache.n_compiled} compiled steps in "
          f"{time.time() - t0:.1f}s")

    # mixed batched requests: every user queries from docs they liked
    rng = np.random.default_rng(9)
    wids = rng.integers(0, n_users, size=n_queries)
    doc_ids = rng.choice(n_docs, n_queries, replace=False)
    queries = corpus[doc_ids] + rng.normal(
        0, 0.01, (n_queries, d)
    ).astype(np.float32)

    t0 = time.time()
    res = svc.query(queries, wids)
    dt = time.time() - t0
    print(f"served {n_queries} personalized queries spanning "
          f"{len(np.unique(res.group_ids))} groups in {dt:.2f}s "
          f"({n_queries / dt:.1f} q/s)")
    for gi, s in sorted(svc.stats_summary().items()):
        print(f"  group {gi}: {s['n_queries']} queries / {s['n_batches']} "
              f"batches, occupancy {s['occupancy']:.2f}, "
              f"mean stop level {s['mean_stop_level']:.1f}")

    # the same requests, one at a time at Poisson arrivals, through the
    # deadline-aware async frontend (shared states / stats / step cache)
    rate_qps, max_delay_ms = 2_000.0, 2.0
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, n_queries))
    svc.reset_stats()
    asvc = AsyncRetrievalService(svc, max_delay_ms=max_delay_ms,
                                 clock=ManualClock())
    ares, waits = replay_open_loop(asvc, queries, wids, arrivals)
    assert (
        np.array_equal(ares.ids, res.ids)
        and np.array_equal(ares.stop_levels, res.stop_levels)
        and np.array_equal(ares.n_checked, res.n_checked)
    ), "async frontend must answer bit-exactly like the sync service"
    occ = svc.mean_occupancy()
    print(f"async replay at {rate_qps:.0f} q/s, deadline {max_delay_ms} ms: "
          f"bit-exact with sync; {asvc.n_launched_full} full / "
          f"{asvc.n_launched_deadline} deadline launches, occupancy "
          f"{occ:.2f} (single-submission baseline "
          f"{1 / svc.cfg.q_batch:.2f}), wait mean "
          f"{1e3 * waits.mean():.2f} ms")

    ok = 0
    for qi, (wid, did) in enumerate(zip(wids, doc_ids)):
        w = users[wid]
        exact = np.argsort(weighted_lp_np(corpus, queries[qi], w, 2.0))[:k]
        got = res.ids[qi][res.ids[qi] >= 0]
        hit = did in got
        ok += hit
        overlap = len(set(got.tolist()) & set(exact.tolist()))
        print(f"  user w{wid} (group {res.group_ids[qi]}): source doc {did} "
              f"{'FOUND' if hit else 'missed'}; top-{k} overlap with exact: "
              f"{overlap}/{k}")
    assert ok >= int(0.75 * n_queries), (
        "service must find the perturbed source doc for most users"
    )
    print("ok")


if __name__ == "__main__":
    main()
