"""End-to-end driver: embed a corpus with an assigned-arch backbone, build
the sharded WLSH index over the embeddings, and serve batched,
weight-personalized k-NN queries through the JAX query engine.

    PYTHONPATH=src python examples/serve_retrieval.py

This is the paper's recommender-system scenario (Sec. 1) on the framework's
own stack: the LM substrate produces the vectors, the WLSH core plans
tables per user-preference weight vector, and the pjit/shard_map engine
answers queries (single-device mesh here; the same code lowers to the
production meshes in launch/dryrun.py).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.datagen import make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.index import IndexConfig, build_state, make_query_step
from repro.models import build_model, init_params


def embed_corpus(n_docs: int, seq_len: int = 32, arch: str = "olmo-1b"):
    """Mean-pooled final hidden states of a reduced backbone = doc vectors."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg, mesh=None)
    params = init_params(model.defs(), jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    vecs = []
    fwd = jax.jit(lambda p, b: model.hidden_states(p, b).mean(axis=1))
    bs = 64
    for i in range(0, n_docs, bs):
        key, k = jax.random.split(key)
        toks = jax.random.randint(k, (min(bs, n_docs - i), seq_len), 0,
                                  cfg.vocab, dtype=jnp.int32)
        vecs.append(np.asarray(fwd(params, {"tokens": toks}), np.float32))
    out = np.concatenate(vecs)
    # shift embeddings to the positive orthant (weighted l_p is used on
    # magnitudes; any affine shift preserves neighbor structure under D_W)
    out = out - out.min(axis=0, keepdims=True)
    return out, cfg


def main():
    n_docs, n_users, k = 4_096, 12, 5
    t0 = time.time()
    corpus, cfg_lm = embed_corpus(n_docs)
    d = corpus.shape[1]
    print(f"embedded {n_docs} docs -> ({n_docs}, {d}) "
          f"with {cfg_lm.name} in {time.time() - t0:.1f}s")

    # user preference weight vectors (the paper's S)
    value_range = float(corpus.max())
    users = make_weight_set(size=n_users, d=d, n_subset=3, n_subrange=10,
                            seed=7)
    cfg = PlanConfig(p=2.0, c=3, n=n_docs, gamma_n=100.0)
    host = WLSHIndex(corpus, users, cfg, tau=500.0, v=d // 4, v_prime=d // 4,
                     value_range=value_range, seed=8)
    print(f"WLSH plan: {len(host.part.groups)} groups, "
          f"{host.beta_total} tables")

    # serve the largest group through the sharded engine
    gi = int(np.argmax([len(g.member_ids) for g in host.part.groups]))
    built = host._group(gi)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    icfg = IndexConfig(
        n=n_docs, d=d, beta=built.fam.beta, q_batch=8, k=k,
        c=int(host.cfg.c), n_levels=int(np.max(built.plan.n_levels)),
        p=2.0, block_n=512, budget=k + int(np.ceil(cfg.gamma * n_docs)),
        vec_dtype="float32", use_pallas=False,
    )
    state = build_state(mesh, icfg, corpus, built.fam)
    step = make_query_step(mesh, icfg)

    # batched requests: each user queries from a doc they liked
    rng = np.random.default_rng(9)
    wids = [int(w) for w in built.plan.member_ids[:8]]
    while len(wids) < 8:
        wids.append(wids[-1])
    doc_ids = rng.choice(n_docs, 8, replace=False)
    queries = corpus[doc_ids] + rng.normal(0, 0.01, (8, d)).astype(np.float32)
    mus, rmins, betas = [], [], []
    for w in wids:
        _, slot, beta_i, mu_i = host._member_params(w)
        mus.append(mu_i)
        rmins.append(built.plan.r_min_members[slot])
        betas.append(beta_i)

    t0 = time.time()
    dists, ids, stop, n_checked = step(
        state, jnp.asarray(queries),
        jnp.asarray(np.stack([host.weights[w] for w in wids]), jnp.float32),
        jnp.asarray(mus, jnp.int32), jnp.asarray(rmins, jnp.float32),
        jnp.asarray(betas, jnp.int32),
    )
    ids = np.asarray(ids)
    print(f"served 8 personalized queries in {time.time() - t0:.2f}s "
          f"(incl. compile)")

    ok = 0
    for qi, (wid, did) in enumerate(zip(wids, doc_ids)):
        w = host.weights[wid]
        exact = np.argsort(weighted_lp_np(corpus, queries[qi], w, 2.0))[:k]
        got = ids[qi][ids[qi] >= 0]
        hit = did in got
        ok += hit
        print(f"  user w{wid}: source doc {did} "
              f"{'FOUND' if hit else 'missed'}; "
              f"top-{k} overlap with exact: "
              f"{len(set(got) & set(exact))}/{k}")
    assert ok >= 6, "engine must find the perturbed source doc for most users"
    print("ok")


if __name__ == "__main__":
    main()
