"""Quickstart: build a WLSH index over synthetic data and answer weighted
k-NN queries with accuracy/space/IO reporting.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's core loop: weight-vector set -> Partition() (greedy
weighted set cover over derived-family candidates) -> per-group hash tables
-> (c,k)-WNN queries with collision counting + virtual rehashing.
"""

import numpy as np

from repro.core.datagen import make_dataset, make_query_set, make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex


def main():
    n, d, n_weights, k = 8_000, 32, 24, 10
    p = 1.0  # fractional/l1 support is the paper's headline: try p=0.5 too

    print(f"data: n={n} d={d}, weight set |S|={n_weights}, l_{p} distance")
    data = make_dataset(n=n, d=d, seed=0)
    weights = make_weight_set(size=n_weights, d=d, n_subset=4,
                              n_subrange=10, seed=1)

    cfg = PlanConfig(p=p, c=3, n=n, gamma_n=100.0)
    idx = WLSHIndex(
        data, weights, cfg,
        tau=1_000.0,            # paper Sec 5.1.3 (l1)
        v=d // 4, v_prime=d // 4,  # bound relaxation, v = v' = d/4
        use_reduction=True,     # collision-threshold reduction
        seed=2,
    )
    naive_tables = int(
        sum(idx.part.groups[int(g)].betas[int(s)]
            for g, s in zip(idx.part.group_of, idx.part.member_slot))
    )
    print(f"partition: {len(idx.part.groups)} table groups, "
          f"{idx.beta_total} tables total "
          f"(naive one-group-per-weight would need ~{naive_tables})")

    qs = make_query_set(data, weights, n_query_points=10, n_query_weights=4,
                        seed=3)
    ratios, ios = [], []
    for q in qs.points:
        for wid in qs.weight_ids:
            res = idx.search(q, weight_id=int(wid), k=k)
            got = res.ids[res.ids >= 0]
            w = idx.weights[int(wid)]
            exact = np.sort(weighted_lp_np(idx.data, q, w, p))[: got.size]
            mine = np.sort(weighted_lp_np(idx.data[got], q, w, p))
            ratios.append(np.mean(mine / np.maximum(exact, 1e-12)))
            ios.append(res.stats.io_blocks)
    print(f"queries: {len(ratios)}  "
          f"avg overall ratio {np.mean(ratios):.4f} (guarantee: <= c={cfg.c})  "
          f"avg I/O {np.mean(ios):.1f} blocks")
    assert np.mean(ratios) < cfg.c


if __name__ == "__main__":
    main()
