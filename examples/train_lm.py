"""End-to-end training driver: an LM trained for a few hundred steps on the
deterministic markov stream, with checkpointing + injected failure +
automatic restart (the fault-tolerance path exercised for real).

    PYTHONPATH=src python examples/train_lm.py               # quick (CPU)
    PYTHONPATH=src python examples/train_lm.py --hundredm    # ~100M params

The quick mode runs the reduced olmo-1b config (~1M params, 200 steps, a
couple of minutes on CPU); --hundredm scales d_model/layers to ~100M params
with fewer steps — the code path is identical.
"""

import argparse
import dataclasses
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import repro.launch.train as T  # noqa: E402
from repro.configs.base import get_config, reduced  # noqa: E402
from repro.models import build_model, count_params  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundredm", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args_in = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="wlsh_train_lm_")
    try:
        args = T.parse_args([
            "--arch", "olmo-1b", "--reduced",
            "--steps", str(args_in.steps or (60 if args_in.hundredm else 200)),
            "--global-batch", "8",
            "--seq-len", "128",
            "--lr", "3e-3",
            "--ckpt-dir", ckpt,
            "--ckpt-every", "25",
            "--log-every", "10",
            "--fail-at", "40",  # injected failure -> restart from checkpoint
        ])
        if args_in.hundredm:
            # ~100M params on the same olmo family:
            # 12 layers x d_model 512 + 32k vocab ~= 1.1e8 params
            cfg = dataclasses.replace(
                reduced(get_config("olmo-1b")),
                name="olmo-100m", d_model=512, n_layers=12,
                n_heads=8, n_kv_heads=8, d_ff=2048, vocab=32_000,
                head_dim=64,
            )
            n = count_params(build_model(cfg, mesh=None).defs())
            print(f"config {cfg.name}: {n / 1e6:.1f}M params")
            orig = T.get_config
            T.get_config = lambda _arch: cfg
            args.reduced = False
            try:
                out = T.train(args)
            finally:
                T.get_config = orig
        else:
            out = T.train(args)
        assert out["restarts"] == 1, "injected failure must trigger a restart"
        assert out["loss_last_avg"] < out["loss_first"] - 0.3, (
            "model must learn the markov stream"
        )
        print("ok:", out)
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
